"""RoundHook — the composable observer pipeline of the session API.

A hook couples one *scan-side* capture with one *host-side* consumer:

* ``capture(diag) -> dict | None`` runs inside the engine's compiled scan
  body on the round diagnostics (traced values). Whatever it returns is
  stacked into extra ``(T, ...)`` trajectory leaves alongside the engine's
  own metrics.
* ``consume(rows, *, t0)`` runs on the host at every segment boundary with
  the segment's stacked trajectory (``t0`` = the segment's first absolute
  round). This is where JSONL streaming, budget enforcement and logging
  live — outside the compiled program.

Four static trace-time declarations let the drivers emit exactly the code
a hook needs and nothing more (collected into a :class:`TraceSpec` by
:func:`hook_trace_spec`):

* ``tap``             — a :class:`repro.audit.transcript.TranscriptTap` to
  thread into ``dpps_step`` (at most one tap-bearing hook per run);
* ``needs_s_half``    — request the perturbed pre-noise state ``s^(t+1/2)``
  in the diagnostics (the exact-sensitivity input, paper Fig. 2);
* ``needs_adjacency`` — request the per-round realized (N, N) adjacency
  under fault injection (:class:`repro.net.stats.NetworkStatsHook`);
* ``needs_wire_stats`` — request the in-scan health diagnostics (NaN/Inf
  wire guard, push-sum mass drift, consensus residual — the
  :class:`repro.obs.watchdog.WatchdogHook` inputs).

Zero-cost contract: with no hooks attached the drivers trace a program
bit-identical to the audit-free engine (the HLO is pinned against the
frozen PR-3 golden modules in tests/test_api.py). With hooks attached the
protocol state trajectory is unchanged — hooks only add scan outputs — and
the built-in hooks reproduce the deprecated ``tap=`` / ``track_real=``
kwarg paths bit-for-bit: :class:`TranscriptHook` and
:class:`RealSensitivityHook` run the exact same traced expressions those
kwargs used to emit, and :class:`LedgerHook` records through the same
:meth:`repro.audit.ledger.PrivacyLedger.record_trajectory`.

The lifecycle around a run: ``prepare(ctx)`` once before the first
segment (the :class:`RunContext` carries the resolved config, so hooks
default their b / gamma_n / sync-interval / wire-dtype from the session
instead of duplicating them as kwargs), then capture/consume per segment,
then ``finish()`` in a ``finally`` (close files even on abort), then
``finish_run(report)`` once the :class:`repro.api.results.RunReport` is
assembled (run-level publication — e.g. the ``run.compile_s`` /
``run.run_s`` wall-split gauges). Hooks that additionally implement a
``segment_span(t0=, n=, start=, execute_end=, consume_end=, compiled=)``
method (duck-typed, like ``network_stats()``) receive per-segment host
timing from the driver — the :class:`repro.obs.timeline.TimelineHook`
seam; attaching one makes the driver sync each segment before timing it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import numpy as np

from repro.core.dpps import DPPSConfig, is_sync_round
from repro.core.privacy import PrivacyAccountant
from repro.core.sensitivity import real_sensitivity

__all__ = [
    "RoundHook",
    "RunContext",
    "TraceSpec",
    "capture_rows",
    "TranscriptHook",
    "LedgerHook",
    "BudgetHook",
    "RealSensitivityHook",
    "MetricsHook",
    "RunAbort",
    "BudgetExhausted",
    "hook_trace_spec",
]


def _default_sink() -> Callable[[str], None]:
    """The obs logger's INFO sink (lazy import: repro.obs is optional at
    hook-construction time only in the sense that the import should not
    run until a default sink is actually needed)."""
    from repro.obs import log_sink

    return log_sink


def _resolve_bus(bus: Any) -> Any:
    """``bus=None`` -> the process-wide default bus (lazy import)."""
    if bus is not None:
        return bus
    from repro.obs import default_bus

    return default_bus()


@dataclasses.dataclass(frozen=True)
class RunContext:
    """What a hook may read about the run it is attached to (``prepare``)."""

    cfg: DPPSConfig            # the resolved protocol config of this run
    plan: Any                  # ProtocolPlan (None for plan-less loop runs)
    n_nodes: int
    rounds: int                # rounds requested (not necessarily executed)
    algorithm: str = "dpps"
    protected: bool = True     # noise on (cfg.noise and gamma_n > 0)
    d_s: int = 0               # shared wire dimension (per-node scalars)


class RoundHook:
    """Base hook: every method is optional; defaults are no-ops.

    Subclasses override ``capture`` (traced, pure — return a dict of new
    trajectory leaves or None) and/or ``consume`` (host side-effects).
    """

    tap: Any = None            # TranscriptTap to thread into dpps_step
    needs_s_half: bool = False  # request s^(t+1/2) in the diagnostics
    needs_adjacency: bool = False   # realized (N, N) adjacency under faults
    needs_wire_stats: bool = False  # in-scan health diagnostics (wd_* rows)

    def prepare(self, ctx: RunContext) -> None:  # noqa: B027 — optional
        pass

    def capture(self, diag: dict[str, Any]) -> dict[str, Any] | None:
        return None

    def consume(self, rows: dict[str, Any], *, t0: int) -> None:  # noqa: B027
        pass

    def finish(self) -> None:  # noqa: B027 — optional
        pass

    def finish_run(self, report: Any) -> None:  # noqa: B027 — optional
        """Called once after the driver assembled the run's
        :class:`repro.api.results.RunReport` (aborted runs included) —
        the place to publish run-level figures that only exist after the
        wall-clock split is known."""


def capture_rows(diag: dict[str, Any], hooks) -> dict[str, Any]:
    """Round diagnostics -> emitted trajectory rows, hook captures merged.

    ``s_half`` (the pre-noise perturbed state, present when a
    ``needs_s_half`` hook requested it) is visible to the hooks' capture
    but never emitted itself — it is the full (N, d) shared state, T
    copies of which would dwarf the metrics. The single definition both
    drivers share: the engine scan body (repro.engine.rounds) and the
    session's per-round loop run the exact same merge, which is what
    keeps loop-vs-engine trajectories bit-comparable with hooks attached.
    """
    view = dict(diag)
    out = {k: v for k, v in view.items() if k != "s_half"}
    for hook in hooks:
        extra = hook.capture(view)
        if extra:
            out.update(extra)
    return out


class TraceSpec(NamedTuple):
    """Everything the compiled round must provide for a hook pipeline.

    The four trace-time switches of the base class, reduced over the
    pipeline: the (at most one) transcript tap, and the three or-folded
    request flags. Both drivers — the engine scan and the session's
    per-round loop — derive their traced program from this one spec.
    """

    tap: Any
    needs_s_half: bool
    needs_adjacency: bool
    needs_wire_stats: bool


def hook_trace_spec(hooks) -> TraceSpec:
    """The :class:`TraceSpec` the compiled round must provide for ``hooks``.

    The single place both drivers (the engine scan and the session's
    per-round loop) derive their trace-time switches from the pipeline;
    enforces the at-most-one-tap rule. Flags are read with ``getattr`` so
    duck-typed hooks (pre-dating the base-class attributes) keep working.
    """
    taps = [h.tap for h in hooks if getattr(h, "tap", None) is not None]
    if len(taps) > 1:
        raise ValueError(
            f"{len(taps)} hooks carry a transcript tap; at most one "
            "tap-bearing hook per run (taps share the tap_* namespace)")
    return TraceSpec(
        tap=taps[0] if taps else None,
        needs_s_half=any(getattr(h, "needs_s_half", False) for h in hooks),
        needs_adjacency=any(getattr(h, "needs_adjacency", False)
                            for h in hooks),
        needs_wire_stats=any(getattr(h, "needs_wire_stats", False)
                             for h in hooks))


# ---------------------------------------------------------------------------
# Built-in hooks (the refactored PR-2 cross-cutting concerns)
# ---------------------------------------------------------------------------


class TranscriptHook(RoundHook):
    """Record the wire-visible transcript (the PR-2 ``tap=`` kwarg).

    The capture itself happens inside ``dpps_step`` (the tap's ``tap_*``
    entries are already part of the diagnostics), so ``capture`` adds
    nothing — which is exactly what keeps this hook bit-identical to the
    kwarg path. ``transcript()`` reassembles the consumed segments into a
    round-indexed :class:`repro.audit.transcript.Transcript`.
    """

    def __init__(self, tap: Any = None):
        from repro.audit.transcript import TranscriptTap

        self.tap = TranscriptTap() if tap is None else tap
        self._segments: list[dict[str, np.ndarray]] = []

    def consume(self, rows: dict[str, Any], *, t0: int) -> None:
        self._segments.append(
            {k: np.asarray(v) for k, v in rows.items() if k.startswith("tap_")})

    def transcript(self):
        from repro.audit.transcript import Transcript

        if not self._segments:
            raise ValueError("no segments consumed yet")
        keys = self._segments[0].keys()
        merged = {k: np.concatenate([s[k] for s in self._segments]) for k in keys}
        return Transcript.from_trajectory(merged)


class RealSensitivityHook(RoundHook):
    """Track the exact network sensitivity per round (the PR-2
    ``track_real=`` kwarg; paper Fig. 2 / Table III validation).

    ``chunk=`` bounds the O(N^2 d) pairwise buffer exactly as the engine's
    old ``track_real`` capture did (bit-identical lax.map row blocks; a
    no-op at N <= 16). ``reals`` / ``violations`` accumulate the consumed
    values host-side (a violation = real exceeding the estimate, which
    Remark 1 says must not happen).
    """

    needs_s_half = True

    def __init__(self, chunk: int = 16):
        self.chunk = chunk
        self.reals: list[float] = []
        self.violations = 0

    def capture(self, diag: dict[str, Any]) -> dict[str, Any]:
        return {"sensitivity_real":
                real_sensitivity(diag["s_half"], chunk=self.chunk)}

    def consume(self, rows: dict[str, Any], *, t0: int) -> None:
        real = np.asarray(rows["sensitivity_real"])
        est = np.asarray(rows["sensitivity_estimate"])
        self.reals.extend(real.tolist())
        self.violations += int(np.sum(real > est + 1e-6))


class LedgerHook(RoundHook):
    """Stream the per-round privacy ledger (the PR-2 ``PrivacyLedger``
    wiring in launch/train.py, as a hook).

    Builds the ledger from the run context at ``prepare`` (b, gamma_n,
    algorithm, wire dtype and sync cadence all come from the session's
    resolved config — no duplicated kwargs); records every consumed
    segment through :meth:`PrivacyLedger.record_trajectory`, so entries
    are bit-identical to the kwarg-era path; closes the JSONL on finish.
    Pass a pre-built ``ledger=`` to keep ownership outside the hook.

    Also a bus producer: each consumed segment publishes
    ``privacy.rounds`` (counter) and ``privacy.epsilon_total`` (gauge) to
    ``bus`` (default: the process bus). The ledger JSONL itself is
    untouched — byte-identical to the pre-bus format.
    """

    def __init__(self, path: str | None = None, budget: float | None = None,
                 mechanism: str = "laplace", ledger: Any = None,
                 bus: Any = None):
        self.path = path
        self.budget = budget
        self.mechanism = mechanism
        self.ledger = ledger
        self.bus = bus
        self._protected = True
        self._sync_interval = 0

    def prepare(self, ctx: RunContext) -> None:
        if self.ledger is None:
            from repro.audit.ledger import PrivacyLedger

            codec = getattr(ctx.plan, "wire", None) \
                if ctx.plan is not None else None
            d_s = int(getattr(ctx, "d_s", 0) or 0)
            if codec is not None and getattr(codec, "active", False):
                wire_codec = codec.name
                bytes_edge = int(codec.payload_bytes(d_s)) if d_s else None
            else:
                # Raw wire: bytes are implied by wire_dtype, so leave the
                # per-edge figure unset and stay entry-identical to a
                # hand-driven PrivacyLedger(wire_dtype=...).
                wire_codec = ctx.cfg.wire_dtype
                bytes_edge = None
            self.ledger = PrivacyLedger(
                b=ctx.cfg.b, gamma_n=ctx.cfg.gamma_n, budget=self.budget,
                mechanism=self.mechanism, path=self.path,
                algorithm=ctx.algorithm, wire_dtype=ctx.cfg.wire_dtype,
                wire_codec=wire_codec, wire_bytes_per_edge=bytes_edge)
        self._protected = ctx.protected
        self._sync_interval = ctx.cfg.sync_interval

    def consume(self, rows: dict[str, Any], *, t0: int) -> None:
        self.ledger.record_trajectory(
            rows, t0=t0, protected=self._protected,
            sync_interval=self._sync_interval)
        n = int(np.asarray(rows["sensitivity_estimate"]).shape[0])
        bus = self.bus = _resolve_bus(self.bus)
        bus.count("privacy.rounds", n, round=t0 + n - 1)
        bus.gauge("privacy.epsilon_total",
                  float(self.ledger.accountant.epsilon_total),
                  round=t0 + n - 1)

    def finish(self) -> None:
        if self.ledger is not None:
            self.ledger.close()

    def summary(self) -> dict[str, Any]:
        return self.ledger.summary()


class RunAbort(RuntimeError):
    """Base of the hook-raised abort family: the session driver catches
    it at segment boundaries, stops the run, and reports ``aborted=True``
    with the message as ``abort_reason``. Subclasses:
    :class:`BudgetExhausted` (strict privacy budget) and
    :class:`repro.obs.watchdog.WatchdogAbort` (strict health watchdog)."""


class BudgetExhausted(RunAbort):
    """Raised by a strict :class:`BudgetHook` once the epsilon ceiling is
    crossed; the session catches it, stops the run, and reports
    ``aborted=True`` (over-budget parameters are never released)."""

    def __init__(self, message: str, round_: int, epsilon_total: float):
        super().__init__(message)
        self.round = round_
        self.epsilon_total = epsilon_total


class BudgetHook(RoundHook):
    """Enforce a total-epsilon ceiling (the PR-2 ``--privacy-budget`` /
    ``--strict-budget`` logic of launch/train.py, as a hook).

    Steps a :class:`PrivacyAccountant` per consumed round (sync rounds are
    unprotected and spend nothing). On first exceeding the budget it warns
    once through ``warn`` — default: the obs logger
    (:func:`repro.obs.log_sink`), so quiet/structured drivers capture it
    through standard ``logging``; inject a callable (e.g. ``print`` or a
    list's ``append``) to override. With ``strict=True`` it raises
    :class:`BudgetExhausted` at the segment boundary — the engine driver's
    enforcement granularity.
    """

    def __init__(self, budget: float, *, strict: bool = False,
                 warn: Callable[[str], None] | None = None, note: str = ""):
        self.budget = budget
        self.strict = strict
        self.warn = warn if warn is not None else _default_sink()
        self.note = note
        self.exceeded_at: int | None = None
        self.accountant: PrivacyAccountant | None = None
        self._protected = True
        self._sync_interval = 0

    def prepare(self, ctx: RunContext) -> None:
        self.accountant = PrivacyAccountant(
            b=ctx.cfg.b, gamma_n=ctx.cfg.gamma_n, budget=self.budget)
        self._protected = ctx.protected
        self._sync_interval = ctx.cfg.sync_interval

    def consume(self, rows: dict[str, Any], *, t0: int) -> None:
        n = int(np.asarray(rows["sensitivity_estimate"]).shape[0])
        for i in range(n):
            t = t0 + i
            protected = (self._protected
                         and not is_sync_round(t, self._sync_interval))
            self.accountant = self.accountant.step(protected=protected)
            if self.accountant.exhausted and self.exceeded_at is None:
                self.exceeded_at = t
                self.warn(
                    f"WARNING: privacy budget {self.budget} exceeded at "
                    f"round {t} (epsilon_total="
                    f"{self.accountant.epsilon_total:.3f}){self.note}")
        if self.strict and self.exceeded_at is not None:
            raise BudgetExhausted(
                f"privacy budget {self.budget} exhausted at round "
                f"{self.exceeded_at}", self.exceeded_at,
                self.accountant.epsilon_total)


class MetricsHook(RoundHook):
    """Host-side metric logging (the ad-hoc ``log_row`` blocks of the old
    drivers). ``fields`` maps output names to trajectory keys; every round
    lands in ``history`` and is printed every ``log_every`` rounds (plus
    the final round when ``total`` is known) through ``formatter``.

    ``print_fn`` defaults to the obs logger (:func:`repro.obs.log_sink`)
    — same lines on stdout, but capturable/silenceable through standard
    ``logging``; inject any callable to override (tests pass
    ``lines.append``). Each history row is also published to ``bus``
    (default: the process bus) as ``metrics.<name>`` gauges.
    """

    def __init__(self, fields: dict[str, str] | None = None,
                 log_every: int = 10, total: int | None = None,
                 formatter: Callable[[dict[str, Any]], str] | None = None,
                 print_fn: Callable[[str], None] | None = None,
                 bus: Any = None):
        self.fields = fields or {"loss": "loss_mean",
                                 "sensitivity": "sensitivity_used"}
        self.log_every = max(int(log_every), 1)
        self.total = total
        self.formatter = formatter or self._default_format
        self.print_fn = print_fn if print_fn is not None else _default_sink()
        self.bus = bus
        self.history: list[dict[str, Any]] = []

    @staticmethod
    def _default_format(row: dict[str, Any]) -> str:
        vals = " ".join(f"{k}={v:.4f}" for k, v in row.items() if k != "step")
        return f"step {row['step']:5d} {vals}"

    def consume(self, rows: dict[str, Any], *, t0: int) -> None:
        cols = {name: np.asarray(rows[key])
                for name, key in self.fields.items() if key in rows}
        if not cols:
            return
        n = next(iter(cols.values())).shape[0]
        bus = self.bus = _resolve_bus(self.bus)
        for i in range(n):
            row = {"step": t0 + i,
                   **{name: float(col[i]) for name, col in cols.items()}}
            self.history.append(row)
            t = row["step"]
            for name, value in row.items():
                if name != "step":
                    bus.gauge(f"metrics.{name}", value, round=t)
            if t % self.log_every == 0 or (self.total is not None
                                           and t == self.total - 1):
                self.print_fn(self.formatter(row))

    def finish_run(self, report: Any) -> None:
        """Publish the report's wall-clock split as ``run.compile_s`` /
        ``run.run_s`` gauges — exporters and the cross-run registry read
        the split off the bus instead of parsing RunReports."""
        bus = self.bus = _resolve_bus(self.bus)
        bus.gauge("run.compile_s", float(report.compile_s))
        bus.gauge("run.run_s", float(report.run_s))
