"""ProtocolSession — the typed front door of the reproduction.

Every consumer used to hand-wire the same setup block: topology ->
``calibrate_constants`` -> config -> ``ProtocolPlan`` -> packed layout ->
jitted segment runner -> ``run_segments`` loop (launch/train.py,
benchmarks/common.py, all four examples carried their own copy).
:meth:`Session.build` owns that block once:

* constant calibration — (C', lambda) from the topology unless the
  :class:`PrivacySpec` pins them (the paper's per-setup tuning, SV.B);
* plan derivation — :class:`repro.engine.ProtocolPlan` from the topology
  (+ mesh) with the deployment knobs (schedule, packed runtime, wire
  dtype, sync cadence, chunking) in one place;
* config stamping — the plan's choices stamped onto
  ``DPPSConfig`` / ``PartPSPConfig`` exactly once;
* base-key / fold-in discipline — one base key; the engine folds the
  absolute round counter carried in the state, so loop and engine drivers
  produce bit-identical trajectories and checkpoints resume the same
  noise stream;
* checkpoint / resume — full-state and consensus-view checkpoints through
  ``repro.checkpoint``.

The run methods return typed :class:`repro.api.results.RunReport` /
:class:`ServeReport` objects, and observers attach as
:class:`repro.api.hooks.RoundHook` pipelines: scan-side ``capture`` adds
trajectory leaves, host-side ``consume`` runs at segment boundaries
(ledger streaming, budget enforcement, logging, transcripts). A hookless
session compiles to HLO identical to the bare engine (pinned in
tests/test_api.py) — the front door costs nothing.

Typical use::

    from repro.api import Session, PrivacySpec, LedgerHook

    session = Session.build(DOutGraph(n_nodes=10, d=2),
                            privacy=PrivacySpec(b=5.0, gamma_n=1e-3))
    report = session.run(200, values=private_values,
                         hooks=[LedgerHook(path="ledger.jsonl")])
    consensus = session.consensus(report.state)
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Iterable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.hooks import (
    RoundHook,
    RunAbort,
    RunContext,
    capture_rows,
    hook_trace_spec,
)
from repro.api.results import RunReport, ServeReport, estimate_wire_bytes
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.dpps import DPPSConfig, DPPSState, dpps_init, dpps_step
from repro.core.dpps import dpps_consensus as _dpps_consensus
from repro.core.dpps import is_sync_round
from repro.core.partition import Partition
from repro.core.partpsp import (
    PartPSPConfig,
    PartPSPState,
    consensus_params,
    make_baseline_config,
    partpsp_init,
    partpsp_step,
)
from repro.core.topology import Topology, calibrate_constants
from repro.core.tree_utils import PyTree
from repro.engine import (
    ProtocolPlan,
    run_decode,
    run_dpps,
    run_partpsp,
    run_segments,
    stack_rounds,
)

__all__ = ["PrivacySpec", "ProtocolSession", "Session"]


@dataclasses.dataclass(frozen=True)
class PrivacySpec:
    """The privacy side of a session, separated from deployment choices.

    ``c_prime`` / ``lam`` default to ``None`` — calibrated to the
    topology's mixing contraction by :func:`calibrate_constants` (the
    principled version of the paper's per-setup tuning). ``mechanism``
    names (or is) a :class:`repro.audit.mechanisms.NoiseMechanism`
    replacing the Eq.-8 Laplace draw; ``None`` keeps the built-in draw
    (bit-identical to ``LaplaceMechanism``).
    """

    b: float = 5.0
    gamma_n: float = 1.0
    noise: bool = True
    c_prime: float | None = None
    lam: float | None = None
    sensitivity_mode: str = "estimated"
    fixed_sensitivity: float = 0.0
    mechanism: Any = None

    def resolve_mechanism(self) -> Any:
        if isinstance(self.mechanism, str):
            from repro.audit.mechanisms import get_mechanism

            return get_mechanism(self.mechanism)
        return self.mechanism


def _own_buffers(state: Any) -> Any:
    """Fresh buffers for every leaf of ``state``.

    The segment runners donate their state argument (XLA aliases the
    packed carry in place); without this copy the *caller's* arrays —
    the ``values=`` tree a consensus state was built over, or the
    session's own ``init_params`` — would be the donated buffers and die
    with the first dispatch.
    """
    return jax.tree_util.tree_map(
        lambda x: x.copy() if hasattr(x, "copy") else x, state)


def _broadcast_nodes(params: PyTree, n_nodes: int) -> PyTree:
    """Single-node params -> node-stacked (every node starts identical).

    ``+ 0.0`` forces a fresh buffer per leaf so XLA never aliases the
    broadcast view into donated protocol carries.
    """
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_nodes,) + x.shape) + 0.0,
        params)


@dataclasses.dataclass(frozen=True, eq=False)
class ProtocolSession:
    """A frozen, fully-derived protocol deployment (see module docstring).

    Build with :meth:`build`; all fields are consistent by construction —
    ``cfg`` and ``train_cfg`` are already plan-stamped, ``partition`` is
    materialized, ``init_params`` are node-stacked. Serve-only sessions
    (``topology=None``) carry a model but no protocol.
    """

    topology: Topology | None
    plan: ProtocolPlan | None
    cfg: DPPSConfig | None               # resolved consensus/protocol config
    train_cfg: PartPSPConfig | None      # resolved training config (or None)
    partition: Partition | None
    model: Any
    loss_fn: Callable | None
    mechanism: Any
    init_params: PyTree | None           # node-stacked initial parameters
    base_key: jax.Array
    algorithm: str
    n_nodes: int

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        topology: Topology | None = None,
        privacy: PrivacySpec | None = None,
        plan: ProtocolPlan | None = None,
        model: Any = None,
        partition: Any = None,
        *,
        params: PyTree | None = None,
        params_stacked: PyTree | None = None,
        algorithm: str = "partpsp",
        gamma_l: float = 0.05,
        gamma_s: float = 0.05,
        clip: float = 100.0,
        schedule: str | None = None,
        sync_interval: int | str | None = None,
        use_kernels: bool | None = None,
        chunk: int = 50,
        packed: bool = True,
        wire_dtype: str = "f32",
        mesh: Any = None,
        faults: Any = None,
        delays: Any = None,
        wire: Any = None,
        seed: int = 0,
        key: jax.Array | None = None,
    ) -> "ProtocolSession":
        """Derive a complete session from topology + privacy + deployment.

        ``privacy`` is a :class:`PrivacySpec` (default: the spec's
        defaults). ``plan`` overrides derivation — when ``None`` it is
        built from the topology with the deployment kwargs (``schedule``,
        ``sync_interval``, ``use_kernels``, ``chunk``, ``packed``,
        ``wire_dtype``, ``mesh``).

        ``model`` makes the session trainable/servable: a bare callable is
        taken as the loss function; an object contributes ``loss_fn`` and
        (for serving) ``prefill`` / ``init_cache`` / ``decode_step``, and
        its ``init(key)`` seeds ``params`` when none are given.
        ``partition`` is a :class:`Partition` or a rules tuple resolved
        against the node-stacked params; ``params`` are single-node
        (broadcast to every node) — pass ``params_stacked`` instead when
        they already carry the leading node axis.

        ``key`` (default ``PRNGKey(seed)``) is both the parameter-init key
        and the run drivers' base key; override per run with
        ``run(..., key=)``.

        ``faults`` (a :class:`repro.net.faults.FaultModel`) attaches
        network fault injection: an active model switches the derived plan
        onto the ``dynamic`` schedule (per-round W masked and
        column-renormalized inside the compiled scan) and the run
        trajectory/ledger record the *realized* out-degrees. Attach a
        :class:`repro.net.stats.NetworkStatsHook` to a run to get the
        realized-network record on ``RunReport.network``.

        ``delays`` (a :class:`repro.net.delays.DelayModel`) attaches the
        bounded-delay async runtime: the engine carries a message mailbox
        through the scan, each sent message gets a seeded random delay
        (timeouts re-credit the sender's self-loop; heterogeneous node
        rates hold skipped nodes), and the per-round
        staleness/timeout/participation stats join the trajectory. An
        inactive model is dropped — the session then runs the synchronous
        program bit-for-bit. Composes with ``faults``.

        ``wire`` (a :class:`repro.wire.WireCodec`) attaches wire
        compression: messages are encoded strictly *after* DP noise
        injection (noise-then-compress — the epsilon accounting is
        untouched) and the byte accounting everywhere (``RunReport``,
        ledger, network stats) reflects the compressed payload. An
        inactive/identity codec is dropped — the session then runs the
        raw f32 wire bit-for-bit. Value codecs compose with ``delays``.
        """
        spec = PrivacySpec() if privacy is None else privacy
        base_key = jax.random.PRNGKey(seed) if key is None else key
        mechanism = spec.resolve_mechanism()

        loss_fn = getattr(model, "loss_fn",
                          model if callable(model) else None)

        cfg = train_cfg = None
        part = None
        stacked = None
        n_nodes = 0
        if topology is not None:
            n_nodes = topology.n_nodes
            if spec.c_prime is None or spec.lam is None:
                cal_c, cal_l = calibrate_constants(topology)
            c_prime = spec.c_prime if spec.c_prime is not None else cal_c
            lam = spec.lam if spec.lam is not None else cal_l
            if plan is None:
                plan = ProtocolPlan.from_topology(
                    topology, mesh=mesh, schedule=schedule,
                    use_kernels=use_kernels, sync_interval=sync_interval,
                    chunk=chunk, packed=packed, wire_dtype=wire_dtype,
                    faults=faults, delays=delays, wire=wire)
            elif faults is not None:
                raise ValueError(
                    "pass faults= either to Session.build (plan derived) or "
                    "to ProtocolPlan.from_topology — not alongside an "
                    "explicit plan=, which already fixed the schedule")
            elif delays is not None:
                raise ValueError(
                    "pass delays= either to Session.build (plan derived) or "
                    "to ProtocolPlan.from_topology — not alongside an "
                    "explicit plan=, which already fixed the schedule")
            elif wire is not None and getattr(wire, "active", False):
                raise ValueError(
                    "pass wire= either to Session.build (plan derived) or "
                    "to ProtocolPlan.from_topology — not alongside an "
                    "explicit plan=, which already fixed the wire format")
            cfg_sync = sync_interval if isinstance(sync_interval, int) else 0

            # The protocol config knows dense/circulant/sparse; "dynamic"
            # is the engine-level fault-masking schedule (dense at step
            # level; a fault-masked sparse plan stays "sparse" throughout).
            cfg_schedule = ("dense" if plan.schedule == "dynamic"
                            else plan.schedule)
            if loss_fn is not None:
                train_cfg = make_baseline_config(
                    algorithm, gamma_l=gamma_l, gamma_s=gamma_s, clip=clip,
                    b=spec.b, gamma_n=spec.gamma_n, c_prime=c_prime, lam=lam,
                    schedule=cfg_schedule, sync_interval=cfg_sync,
                    sensitivity_mode=spec.sensitivity_mode)
                if not spec.noise and algorithm not in ("sgp",):
                    train_cfg = dataclasses.replace(
                        train_cfg, dpps=dataclasses.replace(
                            train_cfg.dpps, noise=False))
                if spec.sensitivity_mode == "fixed" and algorithm != "pedfl":
                    # make_baseline_config carries no fixed-scale knob
                    # (pedfl derives its own 2C); without this stamp a
                    # fixed-mode run would calibrate noise to scale 0.
                    train_cfg = dataclasses.replace(
                        train_cfg, dpps=dataclasses.replace(
                            train_cfg.dpps,
                            fixed_sensitivity=spec.fixed_sensitivity))
                train_cfg = plan.resolve_partpsp(train_cfg)
                cfg = train_cfg.dpps
            else:
                cfg = plan.resolve_dpps(DPPSConfig(
                    b=spec.b, gamma_n=spec.gamma_n, noise=spec.noise,
                    c_prime=c_prime, lam=lam, sync_interval=cfg_sync,
                    sensitivity_mode=spec.sensitivity_mode,
                    fixed_sensitivity=spec.fixed_sensitivity))

            if params_stacked is not None:
                stacked = params_stacked
            elif params is not None:
                stacked = _broadcast_nodes(params, n_nodes)
            elif model is not None and hasattr(model, "init"):
                stacked = _broadcast_nodes(model.init(base_key), n_nodes)

            if stacked is not None and loss_fn is not None:
                if partition is None:
                    partition = ((".*", "shared"),)
                part = (partition if isinstance(partition, Partition)
                        else Partition.from_rules(stacked, tuple(partition),
                                                  default="local"))

        return cls(topology=topology, plan=plan, cfg=cfg,
                   train_cfg=train_cfg, partition=part, model=model,
                   loss_fn=loss_fn, mechanism=mechanism, init_params=stacked,
                   base_key=base_key, algorithm=algorithm, n_nodes=n_nodes)

    # -- state ---------------------------------------------------------------

    def _require_protocol(self) -> None:
        if self.cfg is None or self.plan is None:
            raise ValueError(
                "this session has no protocol (built without a topology); "
                "Session.build(topology=...) enables run()/train()")

    def _attach_mail(self, dpps_state: DPPSState) -> DPPSState:
        """Async sessions carry the message Mailbox from round 0, so every
        segment (and checkpoint) shares one pytree structure — the engine
        would otherwise attach it on first dispatch and recompile."""
        delays = getattr(self.plan, "delays", None)
        if delays is not None and not dpps_state.mail:
            dpps_state = dpps_state._replace(
                mail=delays.init_mailbox(dpps_state.push.s))
        return dpps_state

    def consensus_state(self, values: PyTree) -> DPPSState:
        """Protocol state over per-node private ``values`` (node-stacked)."""
        self._require_protocol()
        return self._attach_mail(dpps_init(values, self.cfg))

    def train_state(self) -> PartPSPState:
        """Fresh PartPSP state from the session's initial parameters."""
        self._require_protocol()
        if self.partition is None or self.init_params is None:
            raise ValueError(
                "training needs model=/params= and partition= at build time")
        state = partpsp_init(self.init_params, self.partition, self.train_cfg)
        return state._replace(dpps=self._attach_mail(state.dpps))

    def consensus(self, state: DPPSState) -> PyTree:
        """Protocol output s-bar (Alg. 1 Output) from a consensus run."""
        return _dpps_consensus(state)

    def consensus_view(self, state: PartPSPState, node: int = 0) -> PyTree:
        """Evaluation/serving params: network-average shared (s-bar) merged
        with ``node``'s personalized local parameters (paper SV.D)."""
        cp = consensus_params(state, self.partition)
        return jax.tree_util.tree_map(lambda x: x[node], cp)

    # -- compiled runners (exposed for HLO pins and power users) -------------

    def _cached_runner(self, kind: str, hooks: tuple, build):
        """Memoize jitted runners per (driver kind, hook pipeline).

        jax.jit's dispatch cache lives on the returned wrapper, so
        rebuilding it every ``run()``/``train()`` would recompile the
        whole scan segment on each call of a reused session. The key
        holds the hook objects themselves (identity-hashed and kept
        alive), so the hookless fast path always hits and a stale id can
        never alias a new pipeline.
        """
        cache = self.__dict__.get("_runners")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_runners", cache)
        key = (kind, hooks)
        if key not in cache:
            cache[key] = build()
        return cache[key]

    def consensus_runner(self, hooks: Sequence[RoundHook] = ()):
        """The jitted segment function :meth:`run` drives. The incoming
        state is donated — XLA aliases the packed carry in place."""
        self._require_protocol()
        hooks = tuple(hooks)
        return self._cached_runner("dpps", hooks, lambda: jax.jit(
            functools.partial(run_dpps, cfg=self.cfg, plan=self.plan,
                              hooks=hooks, mechanism=self.mechanism),
            static_argnames=("rounds",), donate_argnums=(0,)))

    def segment_runner(self, hooks: Sequence[RoundHook] = ()):
        """The jitted training segment function :meth:`train` drives
        (``run_chunk(state, batches, base_key)``; state donated)."""
        self._require_protocol()
        if self.loss_fn is None:
            raise ValueError("training needs model= at build time")
        hooks = tuple(hooks)
        return self._cached_runner("partpsp", hooks, lambda: jax.jit(
            functools.partial(run_partpsp, cfg=self.train_cfg,
                              partition=self.partition,
                              loss_fn=self.loss_fn, plan=self.plan,
                              hooks=hooks, mechanism=self.mechanism),
            donate_argnums=(0,)))

    def step_fn(self, t: int = 0):
        """Jitted per-round reference step (the loop driver's primitive)
        with round-``t`` mixing operands bound statically — the classic
        ``partpsp_step`` closure the seed drivers built by hand."""
        self._require_protocol()
        mix = self.plan.mix_at(t)
        return jax.jit(functools.partial(
            partpsp_step, cfg=self.train_cfg, partition=self.partition,
            loss_fn=self.loss_fn, mechanism=self.mechanism, **mix))

    # -- drivers -------------------------------------------------------------

    @property
    def _protected(self) -> bool:
        return bool(self.cfg is not None and self.cfg.noise
                    and self.cfg.gamma_n > 0)

    def epsilon_spent(self, rounds: int, *, start: int = 0) -> float:
        """Composed epsilon of rounds ``[start, start + rounds)`` (sync
        rounds spend none)."""
        if not self._protected or rounds <= 0:
            return 0.0
        sync = self.cfg.sync_interval
        protected = sum(1 for t in range(start, start + rounds)
                        if not is_sync_round(t, sync))
        return protected * self.cfg.epsilon_per_round

    def _context(self, rounds: int, algorithm: str, d_s: int = 0) -> RunContext:
        return RunContext(cfg=self.cfg, plan=self.plan, n_nodes=self.n_nodes,
                          rounds=rounds, algorithm=algorithm,
                          protected=self._protected, d_s=d_s)

    def _drive(self, segments: Iterator, hooks: Sequence[RoundHook],
               d_s: int, start: int = 0) -> RunReport:
        """Shared host loop: consume hooks per segment, assemble the report.

        A strict hook aborts between segments (any :class:`RunAbort` —
        BudgetExhausted, WatchdogAbort); the report then carries the
        partial run with ``aborted=True``. The report accounts only the
        rounds *this* call executed — resumed runs (``start > 0``) never
        re-count the prefix.

        Wall-clock split: the first segment's wall time (which includes
        tracing + XLA compilation of the scan) is reported as
        ``compile_s``; everything after is steady-state ``run_s``.

        Hooks exposing a ``segment_span`` method (duck-typed — the
        :class:`repro.obs.timeline.TimelineHook` seam) get per-segment
        host timing: with one attached every segment is synced before its
        boundary is stamped, so execute vs hook-consume spans are real
        device time. Without one, only the first segment syncs — the
        hookless path is unchanged.
        """
        t_start = time.time()
        compile_s = 0.0
        trajs: list[dict[str, Any]] = []
        state = None
        done = start
        aborted = False
        reason = None
        span_hooks = [h for h in hooks if hasattr(h, "segment_span")]
        seg_start = t_start
        try:
            for t0, n, state, traj in segments:
                done = t0 + n
                first = not trajs
                exec_end = None
                if first or span_hooks:
                    # End of the first segment = compile + first dispatch;
                    # sync so the boundary is real device time, not the
                    # async dispatch returning early. Span hooks need the
                    # same sync on every segment.
                    jax.block_until_ready(traj)
                    exec_end = time.time()
                    if first:
                        compile_s = exec_end - t_start
                trajs.append(traj)
                for h in hooks:
                    h.consume(traj, t0=t0)
                if span_hooks:
                    consume_end = time.time()
                    for h in span_hooks:
                        h.segment_span(t0=t0, n=n, start=seg_start,
                                       execute_end=exec_end,
                                       consume_end=consume_end,
                                       compiled=first)
                    seg_start = consume_end
        except RunAbort as e:
            aborted = True
            reason = str(e)
        finally:
            for h in hooks:
                h.finish()
        trajectory = {}
        if trajs:
            keys = trajs[0].keys()
            trajectory = {k: np.concatenate([np.asarray(t[k]) for t in trajs])
                          for k in keys}
        executed = done - start
        # Any hook exposing network_stats() (repro.net.stats.
        # NetworkStatsHook — duck-typed so repro.api never imports
        # repro.net) contributes the realized-network record.
        network = None
        for h in hooks:
            stats_fn = getattr(h, "network_stats", None)
            if stats_fn is not None:
                network = stats_fn()
        report = RunReport(
            state=state, trajectory=trajectory, rounds=executed,
            epsilon_spent=self.epsilon_spent(executed, start=start),
            wire_bytes=estimate_wire_bytes(self.plan, self.n_nodes, d_s,
                                           executed),
            compile_s=compile_s,
            run_s=time.time() - t_start - compile_s, aborted=aborted,
            abort_reason=reason, network=network)
        # Run-level publication (run.compile_s / run.run_s gauges, the
        # timeline artifact) — after the report exists, abort included.
        # getattr: duck-typed hooks predating the base class keep working.
        for h in hooks:
            finish_run = getattr(h, "finish_run", None)
            if finish_run is not None:
                finish_run(report)
        return report

    def run(
        self,
        rounds: int,
        *,
        values: PyTree | None = None,
        state: DPPSState | None = None,
        eps_at: Callable[[int], PyTree] | None = None,
        hooks: Iterable[RoundHook] = (),
        key: jax.Array | None = None,
        start: int = 0,
    ) -> RunReport:
        """Run ``rounds`` DPPS protocol rounds through the scan engine.

        ``values`` (node-stacked private values) seeds a fresh state;
        ``state`` resumes an existing one. ``eps_at(t)`` supplies the
        per-round perturbation tree (``None`` = pure consensus, zero
        perturbation). Execution is chunked into ``plan.chunk``-round
        compiled segments; hooks consume at every boundary.
        """
        self._require_protocol()
        if state is None:
            if values is None:
                raise ValueError("run() needs values= (fresh) or state=")
            state = self.consensus_state(values)
        state = _own_buffers(state)
        key = self.base_key if key is None else key
        hooks = tuple(hooks)
        d_s = sum(int(np.prod(x.shape[1:])) if x.ndim > 1 else 1
                  for x in jax.tree_util.tree_leaves(state.push.s))
        for h in hooks:
            h.prepare(self._context(rounds, "dpps", d_s))
        run_chunk = self.consensus_runner(hooks)
        chunk = self.plan.chunk

        def segments():
            st = state
            for t0 in range(start, start + rounds, chunk):
                n = min(chunk, start + rounds - t0)
                if eps_at is None:
                    st, traj = run_chunk(st, None, key, rounds=n)
                else:
                    st, traj = run_chunk(st, stack_rounds(eps_at, t0, n), key)
                yield t0, n, st, traj

        return self._drive(segments(), hooks, d_s, start)

    def train(
        self,
        rounds: int,
        batch_at: Callable[[int], PyTree],
        *,
        state: PartPSPState | None = None,
        hooks: Iterable[RoundHook] = (),
        key: jax.Array | None = None,
        start: int = 0,
        driver: str = "engine",
    ) -> RunReport:
        """Train ``rounds`` PartPSP rounds (Alg. 2).

        ``driver="engine"`` (default) scans ``plan.chunk``-round segments —
        one XLA dispatch each; ``driver="loop"`` is the per-round reference
        path (pytree runtime, one dispatch per round) kept for
        engine-vs-loop comparisons — both fold the absolute round counter
        into the same base key, so trajectories are bit-comparable.
        ``start`` resumes at an absolute round (state carries the counter;
        batches and sync/ledger bookkeeping follow it).
        """
        self._require_protocol()
        if driver not in ("engine", "loop"):
            raise ValueError(f"unknown driver {driver!r}")
        if state is None:
            state = self.train_state()
        state = _own_buffers(state)
        key = self.base_key if key is None else key
        hooks = tuple(hooks)
        for h in hooks:
            h.prepare(self._context(rounds, self.algorithm,
                                    self.partition.d_shared()))
        if driver == "engine":
            run_chunk = self.segment_runner(hooks)
            segments = run_segments(run_chunk, state, batch_at, key,
                                    steps=rounds, chunk=self.plan.chunk,
                                    start=start)
        else:
            segments = self._loop_segments(state, batch_at, key, rounds,
                                           start, hooks)
        return self._drive(segments, hooks, self.partition.d_shared(), start)

    def _loop_segments(self, state, batch_at, key, rounds, start, hooks):
        """Per-round reference driver as a segment stream (T=1 segments).

        Runs the pytree path (no packed layout — the loop is the oracle)
        with per-round mixing operands, so time-varying topologies rotate
        correctly; hook captures run eagerly on the concrete diagnostics.
        """
        spec = hook_trace_spec(hooks)
        codec = getattr(self.plan, "wire", None)
        if codec is not None:
            raise ValueError(
                f"the loop driver runs the pytree path; wire codec "
                f"{codec.name!r} needs the packed buffer — use "
                f"driver='engine'")
        if self.cfg.wire_dtype != "f32":
            raise ValueError("the loop driver runs the pytree path; "
                             "wire_dtype='bf16' needs driver='engine'")
        plan = self.plan
        if plan.schedule == "circulant":
            step = jax.jit(functools.partial(
                partpsp_step, cfg=self.train_cfg, partition=self.partition,
                loss_fn=self.loss_fn, return_s_half=spec.needs_s_half,
                return_wire_stats=spec.needs_wire_stats, tap=spec.tap,
                mechanism=self.mechanism, offsets=plan.offsets))
            mix_for = lambda t: ({"mix_weights":
                                  plan.mix_weights[t % plan.period]}, None)
        elif plan.schedule == "sparse":
            step = jax.jit(functools.partial(
                partpsp_step, cfg=self.train_cfg, partition=self.partition,
                loss_fn=self.loss_fn, return_s_half=spec.needs_s_half,
                return_wire_stats=spec.needs_wire_stats, tap=spec.tap,
                mechanism=self.mechanism))
            if getattr(plan, "dynamic", False):
                # Same fault-key fold as the engine's scan body, on the
                # edge list instead of the dense W (see the dense dynamic
                # branch below).
                want_adj = spec.needs_adjacency

                def mix_for(t):
                    r = t % plan.period
                    vals, net = plan.faults.realize_sparse(
                        plan.sparse_idx[r], plan.sparse_vals[r],
                        plan.faults.fault_key(jax.random.fold_in(key, t)), t,
                        with_adjacency=want_adj)
                    return {"sparse_idx": plan.sparse_idx[r],
                            "sparse_vals": vals}, net
            else:
                mix_for = lambda t: (
                    {"sparse_idx": plan.sparse_idx[t % plan.period],
                     "sparse_vals": plan.sparse_vals[t % plan.period]}, None)
        else:
            step = jax.jit(functools.partial(
                partpsp_step, cfg=self.train_cfg, partition=self.partition,
                loss_fn=self.loss_fn, return_s_half=spec.needs_s_half,
                return_wire_stats=spec.needs_wire_stats, tap=spec.tap,
                mechanism=self.mechanism))
            if getattr(plan, "dynamic", False):
                # Same fault-key fold the engine's scan body uses
                # (FaultModel.fault_key of fold_in(base, t)), so the loop
                # realizes the identical masked W per round and stays
                # bit-comparable to the engine under faults.
                want_adj = spec.needs_adjacency

                def mix_for(t):
                    w, net = plan.faults.realize(
                        plan.ws[t % plan.period],
                        plan.faults.fault_key(jax.random.fold_in(key, t)), t,
                        with_adjacency=want_adj)
                    return {"w": w}, net
            else:
                mix_for = lambda t: ({"w": plan.ws[t % plan.period]}, None)

        if getattr(plan, "delays", None) is not None:
            # Async loop driver: the round's mixing operands (realized by
            # the fault branches above when faults compose) feed the
            # DelayModel's gossip closure instead of the built-in mixing —
            # the same open_round the engine's scan body builds, from the
            # same per-round key fold, so loop and engine trajectories
            # stay bit-identical under delays.
            delays = plan.delays
            needs_ws = spec.needs_wire_stats

            def async_step(state, batch, k, **mix):
                gossip_fn, close = delays.open_round(
                    state.dpps.push, state.dpps.mail, k, state.dpps.t, **mix)
                st2, m = partpsp_step(
                    state, batch, k, cfg=self.train_cfg,
                    partition=self.partition, loss_fn=self.loss_fn,
                    return_s_half=spec.needs_s_half,
                    return_wire_stats=needs_ws, tap=spec.tap,
                    mechanism=self.mechanism, gossip_fn=gossip_fn)
                mail_new, stats = close()
                m = dict(m, **stats)
                if needs_ws:
                    m["wd_mass_drift"] = jnp.abs(
                        stats["async_mass_mean"] - 1.0)
                return st2._replace(
                    dpps=st2.dpps._replace(mail=mail_new)), m

            step = jax.jit(async_step)
            state = state._replace(dpps=self._attach_mail(state.dpps))

        for t in range(start, start + rounds):
            mix, net = mix_for(t)
            state, m = step(state, batch_at(t), jax.random.fold_in(key, t),
                            **mix)
            if net is not None:
                m = dict(m, **net)
            rows = capture_rows(m, hooks)
            yield t, 1, state, jax.tree_util.tree_map(lambda x: x[None], rows)

    # -- profiling -----------------------------------------------------------

    def profile(
        self,
        rounds: int = 50,
        *,
        values: PyTree | None = None,
        state: Any = None,
        batch_at: Callable[[int], PyTree] | None = None,
        hooks: Iterable[RoundHook] = (),
        key: jax.Array | None = None,
        trace_dir: str | None = None,
    ):
        """Profile one compiled segment: wall-clock split + phase breakdown.

        Compiles and runs a single ``min(rounds, plan.chunk)``-round
        segment of the consensus protocol (``values=``/``state=``) or of
        PartPSP training (``batch_at=``), timing trace, compile, and
        execute separately, and captures a ``jax.profiler`` device trace
        of the execute. The trace's per-op times are joined against the
        compiled module's ``op_name`` metadata — where the
        :func:`repro.obs.phase` annotations survive — into a per-phase
        device-time breakdown (:class:`repro.obs.ProfileReport`). When the
        xplane protobuf bindings are unavailable the breakdown degrades to
        empty with a ``note``; the wall-clock split always works.

        ``hooks`` are attached trace-time only (their captures shape the
        profiled program exactly as in :meth:`run`/:meth:`train`); their
        host-side ``consume`` does not run. ``trace_dir`` keeps the raw
        profiler trace on disk (e.g. for TensorBoard); by default it lives
        in a temp dir deleted after the join. The profiled call does NOT
        donate its inputs, so the passed state survives.
        """
        import shutil
        import tempfile

        from repro.obs.trace import ProfileReport, phase_breakdown

        self._require_protocol()
        key = self.base_key if key is None else key
        hooks = tuple(hooks)
        n = min(rounds, self.plan.chunk)
        if batch_at is not None:
            if state is None:
                state = self.train_state()
            fn = functools.partial(
                run_partpsp, cfg=self.train_cfg, partition=self.partition,
                loss_fn=self.loss_fn, plan=self.plan, hooks=hooks,
                mechanism=self.mechanism)
            args = (state, stack_rounds(batch_at, 0, n), key)
        else:
            if state is None:
                if values is None:
                    raise ValueError("profile() needs values=/state= "
                                     "(consensus) or batch_at= (training)")
                state = self.consensus_state(values)
            fn = functools.partial(run_dpps, cfg=self.cfg, plan=self.plan,
                                   hooks=hooks, mechanism=self.mechanism,
                                   rounds=n)
            args = (state, None, key)

        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        trace_s = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
        hlo = compiled.as_text()

        out_dir = trace_dir if trace_dir is not None else tempfile.mkdtemp(
            prefix="repro-obs-profile-")
        try:
            t0 = time.time()
            with jax.profiler.trace(out_dir):
                out = compiled(*args)
                jax.block_until_ready(out)
            execute_s = time.time() - t0
            phases, device_total_s, note = phase_breakdown(hlo, out_dir)
        finally:
            if trace_dir is None:
                shutil.rmtree(out_dir, ignore_errors=True)
        return ProfileReport(
            rounds=n, backend=jax.default_backend(), trace_s=trace_s,
            compile_s=compile_s, execute_s=execute_s, phases=phases,
            device_total_s=device_total_s, trace_dir=trace_dir, note=note)

    # -- cross-run registry --------------------------------------------------

    def _fingerprint(self) -> str:
        """Stable hash of the session's config/plan scalars — the
        registry's comparability stamp for session records (two runs
        with the same fingerprint + scale are the same deployment)."""
        import hashlib
        import json

        plan, cfg = self.plan, self.cfg
        desc = {
            "algorithm": self.algorithm,
            "n_nodes": self.n_nodes,
            "schedule": getattr(plan, "schedule", None),
            "packed": getattr(plan, "packed", None),
            "wire_dtype": getattr(plan, "wire_dtype", None),
            "chunk": getattr(plan, "chunk", None),
            "period": getattr(plan, "period", None),
            "sync_interval": getattr(cfg, "sync_interval", None),
            "b": getattr(cfg, "b", None),
            "gamma_n": getattr(cfg, "gamma_n", None),
            "noise": getattr(cfg, "noise", None),
            "faults": repr(getattr(plan, "faults", None)),
            "delays": repr(getattr(plan, "delays", None)),
            "wire": repr(getattr(plan, "wire", None)),
        }
        blob = json.dumps(desc, sort_keys=True, default=str).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    def record(self, report: RunReport, *, name: str,
               history: str = "BENCH_history.jsonl",
               extra: dict[str, float] | None = None):
        """Append this run to the cross-run registry (lazy import — the
        obs layer stays optional for sessions that never record).

        The record lands as bench ``session/<name>`` with the session's
        scale dict (n_nodes, d_s, rounds, schedule, packed, backend) and
        fingerprint; ``python -m repro.obs.registry check`` then gates
        later runs of the same deployment against this one (us/round,
        wire bytes, epsilon). ``extra`` adds caller metrics (e.g. a
        final consensus error). Returns the appended
        :class:`repro.obs.registry.RunRecord`.
        """
        from repro.obs.registry import RunRecord, append_record

        self._require_protocol()
        push = getattr(report.state, "push", None)
        if push is None and report.state is not None:
            push = getattr(getattr(report.state, "dpps", None), "push", None)
        d_s = 0
        if push is not None:
            d_s = sum(int(np.prod(x.shape[1:])) if x.ndim > 1 else 1
                      for x in jax.tree_util.tree_leaves(push.s))
        chunk = getattr(self.plan, "chunk", 0) or 0
        steady = max(report.rounds - chunk, 0)
        scale = {
            "n_nodes": self.n_nodes, "d_s": d_s,
            "rounds": report.rounds,
            "schedule": getattr(self.plan, "schedule", None),
            "packed": getattr(self.plan, "packed", None),
            "backend": jax.default_backend(),
            "algorithm": self.algorithm,
        }
        rec = RunRecord.from_report(
            name, report, scale=scale, fingerprint=self._fingerprint(),
            backend=jax.default_backend(), steady_rounds=steady,
            extra=extra)
        append_record(rec, history)
        return rec

    # -- serving -------------------------------------------------------------

    @staticmethod
    def _graft_cache(dst, src):
        """Copy a prompt-sized cache prefix into a full-capacity cache."""
        if dst.ndim >= 3 and src.ndim == dst.ndim and dst.shape != src.shape:
            idx = tuple(slice(0, d) for d in src.shape)
            return dst.at[idx].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    def serve(
        self,
        params: PyTree,
        batch: dict[str, Any],
        *,
        gen: int,
        temperature: float = 1.0,
        key: jax.Array | None = None,
        enc: Any = None,
        step_inputs: Any = None,
    ) -> ServeReport:
        """Batched prefill + scan-compiled decode on ``params``.

        Owns the serving plumbing every driver used to hand-roll: jitted
        prefill, rebuilding the KV/SSM cache at prompt+gen capacity with
        the prompt prefix grafted in, and the one-dispatch
        ``repro.engine.run_decode`` generation. ``enc`` is the VLM image
        encoding; embedding-input models must pass precomputed
        ``step_inputs`` of shape (gen-1, B, d_model).
        """
        model = self.model
        if model is None or not hasattr(model, "prefill"):
            raise ValueError("serve() needs a servable model= at build time "
                             "(prefill/init_cache/decode_step)")
        key = self.base_key if key is None else key
        ref = batch.get("tokens", batch.get("labels"))
        b, prompt_len = ref.shape[0], ref.shape[1]

        t0 = time.time()
        logits, cache = jax.jit(model.prefill)(params, batch)
        full = model.init_cache(b, prompt_len + gen)
        cache = jax.tree_util.tree_map(self._graft_cache, full, cache)
        jax.block_until_ready(logits)
        prefill_s = time.time() - t0

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        steps = gen - 1
        cfg = getattr(model, "cfg", None)
        if (cfg is not None and getattr(cfg, "input_mode", None) ==
                "embeddings" and steps > 0 and step_inputs is None):
            raise ValueError("embedding-input models need step_inputs= "
                             "of shape (gen-1, B, d_model)")

        def run_fn(params, cache, tok0, k, enc, step_inputs):
            # params/enc are traced arguments so the compiled scan does
            # not bake the weights in as XLA constants
            def decode_fn(c, step_in, pos):
                return model.decode_step(params, c, step_in, pos, enc)

            return run_decode(decode_fn, cache, tok0, k,
                              start_pos=prompt_len, steps=steps,
                              temperature=temperature,
                              step_inputs=step_inputs)

        t0 = time.time()
        if steps > 0:
            toks, cache = jax.jit(run_fn)(params, cache, tok, key, enc,
                                          step_inputs)
            tokens = jnp.concatenate([tok[:, None], toks.T], axis=1)
        else:
            tokens = tok[:, None]
        jax.block_until_ready(tokens)
        return ServeReport(tokens=tokens, prefill_s=prefill_s,
                           decode_s=time.time() - t0, steps=steps)

    # -- checkpoint / resume -------------------------------------------------

    def save(self, path: str, state: Any, *, step: int = 0,
             metadata: dict | None = None) -> None:
        """Persist a full protocol/training state (resume payload)."""
        save_checkpoint(path, state, step=step, metadata=metadata)

    def restore(self, path: str, template: Any = None) -> tuple[Any, dict]:
        """Restore a state saved with :meth:`save`; resumes the exact
        noise stream (the state carries the absolute round counter the
        engine folds into the base key)."""
        if template is None:
            template = self.train_state()
        return load_checkpoint(path, template)

    def save_consensus(self, path: str, state: PartPSPState, *,
                       step: int = 0, metadata: dict | None = None) -> None:
        """Persist the protocol *output* for serving: s-bar + node 0's
        local params (identical across nodes for the shared part)."""
        save_checkpoint(path, self.consensus_view(state, 0), step=step,
                        metadata=metadata)


Session = ProtocolSession
