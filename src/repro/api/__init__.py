"""repro.api — the typed front door of the DPPS/PartPSP reproduction.

One import gives consumers the whole protocol stack, pre-wired:

* :class:`Session` / :class:`ProtocolSession` (session.py) — built once
  from topology + :class:`PrivacySpec` (+ optional plan/model/partition),
  owning constant calibration, plan derivation, config stamping, packed
  layout, base-key discipline and checkpoint/resume; exposes ``run``,
  ``train``, ``serve``.
* :class:`RoundHook` pipeline (hooks.py) — composable observers with a
  scan-side ``capture`` and a host-side ``consume`` at segment
  boundaries: :class:`TranscriptHook`, :class:`LedgerHook`,
  :class:`BudgetHook`, :class:`RealSensitivityHook`, :class:`MetricsHook`.
  Zero-cost when absent (HLO-pinned), bit-transparent when attached.
* :class:`RunReport` / :class:`ServeReport` (results.py) — typed results
  carrying epsilon spent, wire bytes and wall-clock.
* CLI helpers (cli.py) — shared deployment flags with front-of-house
  validation.

New workloads are new sessions + hooks, not new drivers: every driver in
the repo (launch/train.py, launch/serve.py, benchmarks/, examples/)
builds its runs through this package.
"""
from repro.api.cli import (
    TOPOLOGY_CHOICES,
    add_delay_arguments,
    add_fault_arguments,
    add_protocol_arguments,
    add_topology_arguments,
    delays_from_args,
    faults_from_args,
    make_topology,
    topology_from_args,
    validate_protocol_args,
    wire_from_args,
)
from repro.api.hooks import (
    BudgetExhausted,
    BudgetHook,
    LedgerHook,
    MetricsHook,
    RealSensitivityHook,
    RoundHook,
    RunAbort,
    RunContext,
    TraceSpec,
    TranscriptHook,
    hook_trace_spec,
)
from repro.api.results import RunReport, ServeReport, estimate_wire_bytes
from repro.api.session import PrivacySpec, ProtocolSession, Session

__all__ = [
    "BudgetExhausted",
    "BudgetHook",
    "LedgerHook",
    "MetricsHook",
    "PrivacySpec",
    "ProtocolSession",
    "RealSensitivityHook",
    "RoundHook",
    "RunAbort",
    "RunContext",
    "RunReport",
    "ServeReport",
    "Session",
    "TOPOLOGY_CHOICES",
    "TraceSpec",
    "TranscriptHook",
    "add_delay_arguments",
    "add_fault_arguments",
    "add_protocol_arguments",
    "add_topology_arguments",
    "delays_from_args",
    "estimate_wire_bytes",
    "faults_from_args",
    "hook_trace_spec",
    "make_topology",
    "topology_from_args",
    "validate_protocol_args",
    "wire_from_args",
]
