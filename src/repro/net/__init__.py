"""repro.net — network realism lab: random graphs, faults, realized stats.

The protocol stack (``repro.core`` -> ``repro.engine`` -> ``repro.api``)
assumes *some* per-round doubly stochastic W^(t); this package supplies the
realistic ones and breaks them the way production networks do:

* graphs.py — seeded random / structured topology families (Erdős–Rényi,
  random matchings, small-world, 2-D torus) plus
  :class:`RandomSequenceTopology` for per-round resampling. Counter-based
  draws: ``weight_matrix(t)`` is a pure function of (seed, t).
* faults.py — :class:`FaultModel`: Bernoulli link drops, node churn,
  stragglers, realized *inside* the engine's compiled scan with
  column-stochastic renormalization so push-sum mass conservation (and the
  DP accounting) survives.
* delays.py — :class:`DelayModel`: bounded-delay asynchronous push-sum —
  per-message random delays through an in-scan :class:`Mailbox` carry,
  staleness timeouts re-crediting the sender's self-loop, heterogeneous
  per-node round rates. Mass travels on the messages, so conservation
  holds for any delay pattern; delay-0 is bit-identical to the
  synchronous engine.
* stats.py  — :class:`NetworkStats` / :class:`NetworkStatsHook`: realized
  edges, B-window connectivity of the realized graphs, effective wire
  bytes — attached to ``RunReport.network``.

Wire-up: ``Session.build(topology=..., faults=FaultModel(...))`` threads a
fault model end to end (the plan switches to the ``dynamic`` schedule);
``benchmarks/fig_resilience.py`` sweeps drop rates and tracks
``BENCH_net.json``. The dependency edge to the front door is one-way:
``stats.py`` subclasses :class:`repro.api.hooks.RoundHook`, and
``repro.api`` only ever imports this package inside function bodies
(graphs/faults stay import-free of ``repro.api`` entirely).
"""
from repro.net.delays import DELAY_SALT, DelayModel, Mailbox
from repro.net.faults import FAULT_SALT, FaultModel
from repro.net.graphs import (
    ErdosRenyiGraph,
    RandomMatchingGraph,
    RandomSequenceTopology,
    SmallWorldGraph,
    TorusGraph,
    fold_seed,
    metropolis_weights,
)
from repro.net.stats import NetworkStats, NetworkStatsHook, strongly_connected

__all__ = [
    "DELAY_SALT",
    "DelayModel",
    "Mailbox",
    "FAULT_SALT",
    "FaultModel",
    "ErdosRenyiGraph",
    "RandomMatchingGraph",
    "RandomSequenceTopology",
    "SmallWorldGraph",
    "TorusGraph",
    "NetworkStats",
    "NetworkStatsHook",
    "fold_seed",
    "metropolis_weights",
    "strongly_connected",
]
