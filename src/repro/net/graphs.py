"""Seeded random / structured topology families beyond the paper's circulants.

The paper's protocol only needs each round's W^(t) to be doubly stochastic
with self loops (Def. 1) and the union graph over a B-round window to be
strongly connected (Assumption 1) — nothing restricts it to the two
deterministic circulant families the experiments use. This module adds the
graph families a production deployment actually sees:

* :class:`ErdosRenyiGraph`     — symmetric Erdős–Rényi with Metropolis
  weights (optionally unioned with a ring backbone so Assumption 1 holds at
  any edge probability).
* :class:`RandomMatchingGraph` — a union of ``k`` random directed
  Hamiltonian cycles, ``W = (I + P_1 + … + P_k) / (k+1)``: a genuinely
  *directed* regular gossip graph (sum of permutation matrices is doubly
  stochastic by Birkhoff), strongly connected every single round because
  each cycle alone visits every node.
* :class:`SmallWorldGraph`     — Watts–Strogatz ring lattice with symmetric
  rewiring of the long-range edges (the distance-1 ring is never rewired,
  so connectivity survives any ``beta``), Metropolis weights.
* :class:`TorusGraph`          — 2-D torus grid, degree 4, uniform
  ``(I + A) / 5`` weights. Deterministic, non-circulant in the flat node
  index (the column wrap breaks circulance), so it exercises the dense
  schedule the way a real mesh fabric would.
* :class:`RandomSequenceTopology` — wraps any seeded family and resamples
  it every round with a declared ``period``, the i.i.d.-graph-sequence
  regime of randomized gossip analyses.

Determinism contract: every draw is *counter-based* — ``weight_matrix(t)``
derives a fresh ``numpy`` generator from ``SeedSequence(seed, spawn_key)``
purely from ``(seed, t)``; no Python RNG state is held between calls, so
``ProtocolPlan`` can stack per-round matrices for the scan and the host-side
audit trail can re-derive the exact same graphs (the same discipline the
protocol's JAX key fold-in uses).

All families return *row-convention* matrices (``W[i, j]`` = weight receiver
``i`` applies to sender ``j``'s message — see ``repro.core.topology``) and
keep every diagonal entry strictly positive, which is what the fault
injector (``repro.net.faults``) relies on to renormalize masked columns.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.topology import Topology

__all__ = [
    "ErdosRenyiGraph",
    "RandomMatchingGraph",
    "SmallWorldGraph",
    "TorusGraph",
    "RandomSequenceTopology",
    "fold_seed",
    "metropolis_weights",
]


def _rng(seed: int, *counters: int) -> np.random.Generator:
    """Counter-based generator: a pure function of (seed, counters)."""
    return np.random.default_rng(
        np.random.SeedSequence(entropy=int(seed), spawn_key=tuple(counters)))


def fold_seed(seed: int, counter: int) -> int:
    """Derive a child seed from (seed, counter) — pure, collision-resistant
    (SeedSequence's hash), the host-side analogue of ``jax.random.fold_in``."""
    return int(np.random.SeedSequence(
        entropy=int(seed), spawn_key=(int(counter),)).generate_state(1)[0])


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Doubly stochastic W from a symmetric adjacency (no self loops in adj).

    Metropolis–Hastings: ``W[i, j] = 1 / (1 + max(deg_i, deg_j))`` on edges,
    diagonal takes the slack. Symmetric => doubly stochastic; the diagonal
    is >= 1 / (1 + max_degree) > 0, so the self loop Assumption 1 needs (and
    the fault renormalization relies on) is always present.
    """
    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    if adj.shape != (n, n):
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if not (adj == adj.T).all():
        raise ValueError("metropolis_weights needs a symmetric adjacency")
    adj = adj & ~np.eye(n, dtype=bool)
    deg = adj.sum(axis=1)
    w = np.zeros((n, n), dtype=np.float64)
    ii, jj = np.nonzero(adj)
    w[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def _ring_adjacency(n: int) -> np.ndarray:
    adj = np.zeros((n, n), dtype=bool)
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = True
    adj[(idx + 1) % n, idx] = True
    np.fill_diagonal(adj, False)
    return adj


@dataclasses.dataclass(frozen=True)
class ErdosRenyiGraph(Topology):
    """Symmetric Erdős–Rényi G(N, p) with Metropolis weights.

    Each undirected pair joins with probability ``p`` (drawn once from
    ``seed``; wrap in :class:`RandomSequenceTopology` for a fresh graph per
    round). ``backbone=True`` (default) unions a bidirectional ring so the
    graph is connected — and Assumption 1 holds with B = 1 — at *any* p;
    ``backbone=False`` is the textbook G(N, p), which may disconnect.
    """

    p: float = 0.3
    seed: int = 0
    backbone: bool = True

    def __post_init__(self):
        if self.n_nodes < 2:
            raise ValueError("ErdosRenyiGraph needs N >= 2")
        if not (0.0 <= self.p <= 1.0):
            raise ValueError(f"edge probability p={self.p} must be in [0, 1]")

    def offsets(self, t: int) -> Sequence[int] | None:
        return None

    def weight_matrix(self, t: int) -> np.ndarray:
        n = self.n_nodes
        rng = _rng(self.seed, 0)
        upper = np.triu(rng.random((n, n)) < self.p, k=1)
        adj = upper | upper.T
        if self.backbone:
            adj |= _ring_adjacency(n)
        return metropolis_weights(adj)


@dataclasses.dataclass(frozen=True)
class RandomMatchingGraph(Topology):
    """Union of ``k`` random directed Hamiltonian cycles (regular digraph).

    ``W = (I + P_1 + … + P_k) / (k + 1)`` where each ``P_j`` is the
    permutation matrix of a uniformly random n-cycle: every node sends
    weight ``1/(k+1)`` along each cycle plus its self loop (up to ``k``
    distinct out-neighbours — overlapping cycles stack their weight) —
    the directed analogue of round-robin matchings. A sum of permutation
    matrices is doubly stochastic by construction, and a single n-cycle is
    already strongly connected, so Assumption 1 holds with B = 1 every
    round regardless of the draw.
    """

    k: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.n_nodes < 2:
            raise ValueError("RandomMatchingGraph needs N >= 2")
        if not (1 <= self.k < self.n_nodes):
            raise ValueError(
                f"matching count k={self.k} must be in [1, N-1={self.n_nodes - 1}]")

    def offsets(self, t: int) -> Sequence[int] | None:
        return None

    def weight_matrix(self, t: int) -> np.ndarray:
        n = self.n_nodes
        w = np.eye(n, dtype=np.float64)
        for j in range(self.k):
            order = _rng(self.seed, 1, j).permutation(n)
            # order[i] sends to order[i + 1] — one directed n-cycle.
            w[np.roll(order, -1), order] += 1.0
        return w / (self.k + 1)


@dataclasses.dataclass(frozen=True)
class SmallWorldGraph(Topology):
    """Watts–Strogatz small world with connectivity-preserving rewiring.

    Ring lattice (each node linked to its ``k`` nearest neighbours per
    side) whose long-range edges (lattice offset >= 2) are each rewired —
    symmetrically, to a uniform non-neighbour — with probability ``beta``.
    The distance-1 ring is never rewired, so the graph stays connected for
    every ``beta`` in [0, 1]; Metropolis weights keep W doubly stochastic
    under the resulting irregular degrees.
    """

    k: int = 2
    beta: float = 0.1
    seed: int = 0

    def __post_init__(self):
        if self.n_nodes < 4:
            raise ValueError("SmallWorldGraph needs N >= 4")
        if not (1 <= self.k <= (self.n_nodes - 1) // 2):
            raise ValueError(
                f"lattice degree k={self.k} must be in [1, (N-1)//2="
                f"{(self.n_nodes - 1) // 2}] for N={self.n_nodes}")
        if not (0.0 <= self.beta <= 1.0):
            raise ValueError(f"rewiring beta={self.beta} must be in [0, 1]")

    def offsets(self, t: int) -> Sequence[int] | None:
        return None

    def weight_matrix(self, t: int) -> np.ndarray:
        n = self.n_nodes
        rng = _rng(self.seed, 2)
        adj = _ring_adjacency(n)
        for off in range(2, self.k + 1):
            for i in range(n):
                j = (i + off) % n
                if rng.random() < self.beta:
                    # Rewire (i, j) -> (i, m): keep it symmetric so the
                    # Metropolis weights stay doubly stochastic.
                    candidates = np.flatnonzero(~adj[i] & (np.arange(n) != i))
                    if candidates.size:
                        j = int(rng.choice(candidates))
                adj[i, j] = adj[j, i] = True
        return metropolis_weights(adj)


@dataclasses.dataclass(frozen=True)
class TorusGraph(Topology):
    """2-D torus grid (rows x cols = N), 4-neighbour wraparound links.

    ``rows=0`` derives the most-square factorization of N (and raises an
    actionable error when N is prime — a 1-wide torus is just a ring; use
    :class:`repro.core.topology.RingGraph` for that). Uniform degree 4
    makes the Metropolis weights the flat ``(I + A) / 5``. Deterministic
    and symmetric, but *not* circulant in the flattened node index (the
    column wrap jumps rows), so it runs on the dense schedule.
    """

    rows: int = 0

    def __post_init__(self):
        rows = self.rows or self._derive_rows(self.n_nodes)
        if rows < 2 or self.n_nodes % rows or self.n_nodes // rows < 2:
            raise ValueError(
                f"TorusGraph needs N = rows x cols with rows, cols >= 2; "
                f"got N={self.n_nodes}, rows={self.rows or rows}"
                + ("" if self.rows else
                   f" (N={self.n_nodes} has no 2-D factorization — use "
                   "RingGraph for a 1-D cycle)"))
        object.__setattr__(self, "rows", rows)

    @staticmethod
    def _derive_rows(n: int) -> int:
        for r in range(int(math.isqrt(n)), 1, -1):
            if n % r == 0:
                return r
        return 1

    @property
    def cols(self) -> int:
        return self.n_nodes // self.rows

    def offsets(self, t: int) -> Sequence[int] | None:
        return None

    def weight_matrix(self, t: int) -> np.ndarray:
        n, rows, cols = self.n_nodes, self.rows, self.cols
        adj = np.zeros((n, n), dtype=bool)
        for r in range(rows):
            for c in range(cols):
                i = r * cols + c
                for rr, cc in (((r + 1) % rows, c), ((r - 1) % rows, c),
                               (r, (c + 1) % cols), (r, (c - 1) % cols)):
                    j = rr * cols + cc
                    if j != i:
                        adj[i, j] = adj[j, i] = True
        return metropolis_weights(adj)


@dataclasses.dataclass(frozen=True)
class RandomSequenceTopology(Topology):
    """Resample a seeded base family every round, cycling with ``period``.

    ``W^(t)`` is the base family redrawn with the counter-derived seed
    ``fold_seed(base.seed, t % period)`` — a fresh independent graph per
    round, repeating after ``period`` rounds so :class:`ProtocolPlan` can
    stack the finite sequence for the compiled scan. The base must carry a
    ``seed`` field (the random families above do); the declared period is
    also what the Assumption-1 window check and ``sync_interval='auto'``
    key off.
    """

    base: Topology | None = None
    period: int = 8

    def __post_init__(self):
        if self.base is None:
            raise ValueError("RandomSequenceTopology needs a base= topology")
        if not hasattr(self.base, "seed"):
            raise ValueError(
                f"base {type(self.base).__name__} has no seed field; only "
                "seeded random families can be resampled per round")
        if self.base.n_nodes != self.n_nodes:
            raise ValueError(
                f"base n_nodes={self.base.n_nodes} != wrapper "
                f"n_nodes={self.n_nodes}")
        if self.period < 1:
            raise ValueError(f"period={self.period} must be >= 1")

    def _at(self, t: int) -> Topology:
        seed = fold_seed(self.base.seed, t % self.period)
        return dataclasses.replace(self.base, seed=seed)

    def offsets(self, t: int) -> Sequence[int] | None:
        return None

    def weight_matrix(self, t: int) -> np.ndarray:
        return self._at(t).weight_matrix(0)
