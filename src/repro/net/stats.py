"""NetworkStats — what the network actually did during a run.

The protocol's cost and its privacy story both live on the *realized*
communication graph: under fault injection (``repro.net.faults``) the
nominal topology says little about what crossed the wire. This module
turns the engine's per-round network diagnostics into a typed record:

* :class:`NetworkStats` — per-round realized edge counts, dropped edges,
  realized out-degree floor, Assumption-1 B-window connectivity over the
  *realized* graphs, and effective wire bytes (realized edges x payload)
  next to the nominal estimate.
* :class:`NetworkStatsHook` — the session hook that collects them. A real
  :class:`repro.api.hooks.RoundHook` subclass since the trace-time
  declarations (including ``needs_adjacency``) moved into the base class:
  the import edge ``repro.net -> repro.api`` is safe because ``repro.api``
  defers every ``repro.net`` import into function bodies (the historical
  duck-typing existed only to keep that edge one-way). It also publishes
  per-segment realized/dropped edge counters to the obs bus
  (``net.realized_edges`` / ``net.dropped_edges``).

Fault-free runs get stats too: when the trajectory carries no ``net_*``
rows (no masking code was emitted), the hook reconstructs the nominal
per-round adjacency from the plan (circulant offsets, the sparse edge
list, or stacked dense matrices) — the realized graph *is* the nominal
graph then.

``ProtocolSession`` attaches the finished stats to
``RunReport.network`` for any hook exposing a ``network_stats()`` method.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.api.hooks import RoundHook, _resolve_bus

__all__ = ["NetworkStats", "NetworkStatsHook", "strongly_connected"]


def strongly_connected(adj: np.ndarray) -> bool:
    """Strong connectivity of a (recv, send) adjacency via boolean powers."""
    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    reach = adj | np.eye(n, dtype=bool)
    for _ in range(max(n.bit_length(), 1)):
        nxt = reach | (reach @ reach)
        if (nxt == reach).all():
            break
        reach = nxt
    return bool(reach.all())


@dataclasses.dataclass
class NetworkStats:
    """Realized-network record of one run (all per-round arrays length T)."""

    rounds: int
    n_nodes: int
    b_window: int
    realized_edges: np.ndarray       # (T,) non-self directed edges that fired
    dropped_edges: np.ndarray        # (T,) nominal-minus-realized edge count
    out_degree_min: np.ndarray       # (T,) smallest realized sender degree
    connected_windows: int           # B-windows whose union graph is strong
    windows: int                     # total B-windows checked
    effective_bytes: int             # realized edges x per-message payload
    nominal_bytes: int               # fault-free bytes on the SAME topology
    #   support (realized + dropped edges) — not RunReport.wire_bytes's
    #   all-to-all dense estimate, so effective/nominal isolates the
    #   faults' effect rather than the graph's sparsity
    wire_codec: str = "f32"          # active repro.wire codec (or dtype)
    payload_bytes: int = 0           # post-compression bytes per message
    compression_ratio: float = 1.0   # raw f32 message bytes / payload_bytes

    @property
    def all_windows_connected(self) -> bool:
        return self.windows > 0 and self.connected_windows == self.windows

    @property
    def drop_fraction(self) -> float:
        total = self.realized_edges.sum() + self.dropped_edges.sum()
        return float(self.dropped_edges.sum() / total) if total else 0.0

    def summary(self) -> dict[str, Any]:
        return {
            "rounds": self.rounds,
            "n_nodes": self.n_nodes,
            "b_window": self.b_window,
            "realized_edges_mean": float(self.realized_edges.mean())
            if self.rounds else 0.0,
            "dropped_edges_total": int(self.dropped_edges.sum()),
            "drop_fraction": round(self.drop_fraction, 4),
            "out_degree_min": int(self.out_degree_min.min())
            if self.rounds else 0,
            "connected_windows": f"{self.connected_windows}/{self.windows}",
            "all_windows_connected": self.all_windows_connected,
            "effective_bytes": self.effective_bytes,
            "nominal_bytes": self.nominal_bytes,
            "wire_codec": self.wire_codec,
            "payload_bytes": self.payload_bytes,
            "compression_ratio": round(self.compression_ratio, 3),
        }


class NetworkStatsHook(RoundHook):
    """Collect :class:`NetworkStats` from a session run.

    ``b_window`` is the Assumption-1 window length the connectivity check
    slides over the realized graphs; ``None`` defaults to the plan's
    period (the declared B of the nominal topology). The finished stats
    are returned by :meth:`network_stats` and attached to
    ``RunReport.network`` by the session driver.

    ``needs_adjacency`` (a base-class trace declaration) asks the dynamic
    engine to emit the per-round realized (N, N) adjacency into the
    trajectory — only runs carrying this hook pay for that leaf; fault
    runs without it record just the (N,) out-degrees and the dropped-edge
    scalar. Each consumed segment's realized/dropped non-self edge totals
    go to the obs ``bus`` as counters.
    """

    needs_adjacency = True

    def __init__(self, b_window: int | None = None, *, bus: Any = None):
        self.b_window = b_window
        self.bus = bus
        self._adj: list[np.ndarray] = []
        self._out_deg: list[np.ndarray] = []
        self._dropped: list[np.ndarray] = []
        self._ctx = None

    # -- hook protocol -------------------------------------------------------

    def prepare(self, ctx) -> None:
        self._ctx = ctx

    def capture(self, diag: dict[str, Any]) -> dict[str, Any] | None:
        return None  # the engine already emits net_* rows when faults are on

    def _publish_async(self, rows: dict[str, Any], t0: int) -> None:
        """Async trajectories (ProtocolPlan.delays): staleness histogram,
        timeout counter and participation gauge onto the bus. The per-delay
        counts arrive pre-binned (``async_delay_hist`` is (T, B+1)), so
        each bin becomes one weighted histogram observation per segment
        instead of one event per message."""
        if "async_delay_hist" not in rows:
            return
        hist = np.asarray(rows["async_delay_hist"])          # (T, B+1)
        t_last = t0 + hist.shape[0] - 1
        bus = self.bus = _resolve_bus(self.bus)
        for d in range(hist.shape[1]):
            delivered = int(hist[:, d].sum())
            if delivered:
                bus.observe("net.staleness", float(d), count=delivered,
                            round=t_last)
        bus.count("net.timeouts",
                  int(np.asarray(rows["async_timeouts"]).sum()),
                  round=t_last)
        bus.gauge("net.participation",
                  float(np.asarray(rows["async_participated"]).mean()),
                  round=t_last)

    def consume(self, rows: dict[str, Any], *, t0: int) -> None:
        self._publish_async(rows, t0)
        if "net_adj" in rows:
            adj = np.asarray(rows["net_adj"], dtype=bool)
            out_deg = np.asarray(rows["net_out_degree"])
            dropped = np.asarray(rows["net_dropped_edges"])
        elif "net_out_degree" in rows:
            raise ValueError(
                "faulted trajectory carries no net_adj rows — this hook's "
                "needs_adjacency was overridden to False; the realized "
                "window-connectivity check needs the per-round adjacency")
        else:
            n_rounds = int(np.asarray(
                next(iter(rows.values()))).shape[0]) if rows else 0
            adj, out_deg, dropped = self._nominal_rows(t0, n_rounds)
        self._adj.append(adj)
        self._out_deg.append(out_deg)
        self._dropped.append(dropped)
        if adj.shape[0]:
            eye = np.eye(adj.shape[1], dtype=bool)
            t_last = t0 + adj.shape[0] - 1
            bus = self.bus = _resolve_bus(self.bus)
            bus.count("net.realized_edges",
                      int((adj & ~eye).sum()), round=t_last)
            bus.count("net.dropped_edges", int(dropped.sum()), round=t_last)
            bus.gauge("wire.compression_ratio", self._wire_payload()[2],
                      round=t_last)

    def finish(self) -> None:  # stats are pulled, not pushed
        pass

    # -- assembly ------------------------------------------------------------

    def _nominal_rows(self, t0: int, n_rounds: int):
        """Fault-free rounds: realized == nominal, rebuilt from the plan."""
        plan, n = self._ctx.plan, self._ctx.n_nodes
        adj = np.zeros((n_rounds, n, n), dtype=bool)
        idx = np.arange(n)
        for i in range(n_rounds):
            r = (t0 + i) % max(int(plan.period), 1)
            if plan.schedule == "circulant":
                wts = np.asarray(plan.mix_weights)[r]
                for off, wt in zip(plan.offsets, wts):
                    if wt > 0:
                        adj[i, (idx + off) % n, idx] = True
            elif getattr(plan, "sparse_idx", None) is not None:
                # Padded CSR: slot (recv, k) is a live edge iff its weight
                # is positive (pads carry the receiver's index, weight 0).
                send = np.asarray(plan.sparse_idx)[r]   # (N, K)
                live = np.asarray(plan.sparse_vals)[r] > 0.0
                recv = np.broadcast_to(idx[:, None], send.shape)
                adj[i, recv[live], send[live]] = True
            else:
                adj[i] = np.asarray(plan.ws)[r] > 0.0
        eye = np.eye(n, dtype=bool)
        nonself = adj & ~eye
        out_deg = nonself.sum(axis=1)  # (T, N) per sender column
        adj |= eye
        return adj, out_deg, np.zeros((n_rounds,), dtype=np.int64)

    def _wire_payload(self) -> tuple[str, int, float]:
        """(codec name, post-compression message bytes, compression ratio).

        The same per-message accounting as
        :func:`repro.api.results.estimate_wire_bytes`: an active wire
        codec (``ProtocolPlan.wire``) owns it, otherwise the wire dtype
        does. The ratio compares against the raw 4-byte-per-element f32
        message — it is what the ``wire.compression_ratio`` gauge reports.
        """
        d_s = int(getattr(self._ctx, "d_s", 0) or 0)
        codec = getattr(self._ctx.plan, "wire", None)
        if codec is not None and getattr(codec, "active", False):
            name, msg_bytes = codec.name, int(codec.payload_bytes(d_s))
        else:
            name = self._ctx.cfg.wire_dtype
            msg_bytes = d_s * (2 if name == "bf16" else 4)
        ratio = (4.0 * d_s / msg_bytes) if msg_bytes else 1.0
        return name, msg_bytes, ratio

    def network_stats(self) -> NetworkStats | None:
        if self._ctx is None or not self._adj:
            return None
        adj = np.concatenate(self._adj, axis=0)
        out_deg = np.concatenate(self._out_deg, axis=0)
        dropped = np.concatenate(self._dropped, axis=0)
        rounds, n = adj.shape[0], adj.shape[1]
        eye = np.eye(n, dtype=bool)
        realized = (adj & ~eye).sum(axis=(1, 2))

        b = int(self.b_window or max(int(self._ctx.plan.period), 1))
        windows = connected = 0
        for w0 in range(0, rounds - b + 1, b):
            union = adj[w0:w0 + b].any(axis=0)
            windows += 1
            connected += int(strongly_connected(union))

        codec_name, msg_bytes, ratio = self._wire_payload()
        payload = msg_bytes + 8  # message + a_i + S_i scalars
        # Nominal = what the fault-free topology would have sent: per round,
        # realized + dropped is exactly the nominal non-self support
        # (FaultModel.realize defines dropped as nominal minus realized).
        nominal_edges = int(realized.sum() + dropped.sum())

        return NetworkStats(
            rounds=rounds, n_nodes=n, b_window=b,
            realized_edges=realized, dropped_edges=dropped,
            out_degree_min=out_deg.min(axis=1) if rounds else out_deg,
            connected_windows=connected, windows=windows,
            effective_bytes=int(realized.sum()) * payload,
            nominal_bytes=nominal_edges * payload,
            wire_codec=codec_name, payload_bytes=msg_bytes,
            compression_ratio=ratio)
