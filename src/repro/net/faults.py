"""FaultModel — in-scan network fault injection for the protocol engine.

Real deployments drop packets, lose nodes, and wait on stragglers; the
protocol survives all three *because* it is push-sum: Eq. 9 only needs each
round's realized weight matrix to be **column**-stochastic (every sender's
outgoing mass sums to 1) — the ``a``-weights absorb the lost double
stochasticity and the Eq. 10 correction ``y = s / a`` stays unbiased. This
module models the faults and produces exactly that realized matrix:

1. start from the round's *nominal* doubly stochastic W^(t);
2. knock out edges — per-edge Bernoulli link drops (``drop_rate``), whole
   nodes on a churn schedule (``churn``: the node neither sends nor
   receives while down), per-sender straggler rounds (``straggler_rate``:
   the node's messages miss the round everywhere);
3. self loops are never dropped (a node always keeps its own value);
4. renormalize each surviving column to sum exactly to 1 — mass
   conservation, and with it the push-sum w-weight correction, holds at
   any drop rate (pinned in tests/test_net.py).

Randomness is drawn from a JAX key *inside* the compiled scan:
``fault_key`` folds a fixed salt into the round key the engine already
derives (``fold_in(base_key, t)``), so fault masks are (a) independent of
the Eq.-8 noise stream that consumes the round key directly, (b) identical
between the scan engine and the per-round loop driver, and (c)
re-derivable by host-side audit tooling from the base key alone.

DP accounting stays honest under faults because the masks are drawn
independently of the data — the noised message a dropped edge *would* have
carried is the same Lap(S/b)-protected value its surviving siblings carry
— but the audit trail must record what actually crossed the wire:
:meth:`realize` returns per-round diagnostics (realized out-degrees,
dropped-edge count, realized adjacency) that the engine merges into the
trajectory for the ledger (``repro.audit.ledger``) and
:class:`repro.net.stats.NetworkStatsHook`.

A ``FaultModel()`` with every knob at its default is *inactive*: the plan
and engine emit no masking code at all, so a faults-disabled run is
bit-identical to the fault-free engine (an acceptance pin, not an
accident).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["FaultModel", "FAULT_SALT"]

# Folded into the round key to derive the fault stream. The round key
# itself seeds the Eq.-8 noise draw, so the fault mask must come from a
# distinct fold — never from the raw round key.
FAULT_SALT = 0x4E455446  # "NETF"


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Static description of the network's failure behaviour.

    Fields:
      drop_rate       per-(non-self)-edge Bernoulli drop probability per
                      round — independent across edges and rounds.
      churn           node downtime schedule: tuple of ``(node, t_down,
                      t_up)`` half-open round intervals. A down node is
                      isolated — it neither sends nor receives, keeps its
                      own state, and rejoins at ``t_up``.
      straggler_rate  per-node Bernoulli probability that a node's
                      outgoing messages miss the round entirely (the
                      receivers renormalize; the straggler still hears
                      others).
      seed            reserved fold for running several independent fault
                      streams off one base key.

    Frozen and hashable — it rides on :class:`repro.engine.ProtocolPlan`
    as a trace-time constant.
    """

    drop_rate: float = 0.0
    churn: tuple[tuple[int, int, int], ...] = ()
    straggler_rate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if not (0.0 <= self.drop_rate < 1.0):
            raise ValueError(f"drop_rate={self.drop_rate} must be in [0, 1)")
        if not (0.0 <= self.straggler_rate < 1.0):
            raise ValueError(
                f"straggler_rate={self.straggler_rate} must be in [0, 1)")
        windows: dict[int, list[tuple[int, int]]] = {}
        for entry in self.churn:
            if len(entry) != 3:
                raise ValueError(
                    f"churn entries are (node, t_down, t_up); got {entry!r}")
            node, t_down, t_up = entry
            for name, val in (("node", node), ("t_down", t_down),
                              ("t_up", t_up)):
                if not isinstance(val, int) or isinstance(val, bool):
                    raise ValueError(
                        f"churn {name}={val!r} must be an int (entry "
                        f"{entry!r}); floats/strings are silently wrong in "
                        "the traced round comparison")
            if node < 0:
                raise ValueError(f"churn node {node} must be >= 0")
            if not t_down < t_up:
                raise ValueError(
                    f"churn interval [{t_down}, {t_up}) is empty for node "
                    f"{node}")
            for lo, hi in windows.get(node, ()):
                if t_down < hi and lo < t_up:
                    raise ValueError(
                        f"churn windows [{lo}, {hi}) and [{t_down}, {t_up}) "
                        f"overlap for node {node}; merge them into one "
                        "interval per downtime")
            windows.setdefault(node, []).append((t_down, t_up))

    @property
    def active(self) -> bool:
        """Whether any masking code needs to be emitted at all."""
        return (self.drop_rate > 0.0 or bool(self.churn)
                or self.straggler_rate > 0.0)

    # -- key discipline ------------------------------------------------------

    def fault_key(self, round_key: jax.Array) -> jax.Array:
        """The fault stream's key for a round, derived from the engine's
        per-round key (``fold_in(base_key, t)``) by folding the salt (and
        the model's ``seed``) — independent of the noise draw that
        consumes ``round_key`` directly."""
        return jax.random.fold_in(
            jax.random.fold_in(round_key, FAULT_SALT), self.seed)

    # -- in-scan realization -------------------------------------------------

    def up_mask(self, t, n_nodes: int) -> jnp.ndarray:
        """(N,) bool: node currently up under the churn schedule (traced t)."""
        up = jnp.ones((n_nodes,), dtype=bool)
        if not self.churn:
            return up
        # n_nodes is only known here (the model is topology-agnostic until
        # realized); an out-of-range id would otherwise be a silent no-op.
        bad = sorted({c[0] for c in self.churn if c[0] >= n_nodes})
        if bad:
            raise ValueError(
                f"churn nodes {bad} out of range for N={n_nodes} "
                f"(valid ids 0..{n_nodes - 1})")
        nodes = jnp.asarray([c[0] for c in self.churn], jnp.int32)
        downs = jnp.asarray([c[1] for c in self.churn], jnp.int32)
        ups = jnp.asarray([c[2] for c in self.churn], jnp.int32)
        t = jnp.asarray(t, jnp.int32)
        down_now = (t >= downs) & (t < ups)  # (K,)
        hit = (jnp.arange(n_nodes, dtype=jnp.int32)[:, None]
               == nodes[None, :]) & down_now[None, :]
        return ~jnp.any(hit, axis=-1)

    def realize(
        self, w: jnp.ndarray, key: jax.Array, t, *,
        with_adjacency: bool = False,
    ) -> tuple[jnp.ndarray, dict[str, Any]]:
        """Nominal W -> (realized column-stochastic W, round diagnostics).

        Jit-safe with traced ``t`` / ``key`` / ``w``. The nominal W must
        have a strictly positive diagonal (every family in
        ``repro.core.topology`` / ``repro.net.graphs`` does, per
        Assumption 1) — the kept self loop is what guarantees every
        column's surviving mass is positive before renormalization.

        Diagnostics (merged into the engine trajectory):
          net_out_degree     (N,) int32 realized non-self out-edges/sender
          net_dropped_edges  ()  int32 nominal-minus-realized edge count
          net_adj            (N, N) bool realized adjacency (recv, send) —
                             only with ``with_adjacency`` (the engine sets
                             it when a hook declares ``needs_adjacency``,
                             e.g. NetworkStatsHook's window-connectivity
                             check; a (T, N, N) trajectory leaf is real
                             memory at fleet scale, so nobody pays for it
                             unread)
        """
        n = w.shape[0]
        eye = jnp.eye(n, dtype=bool)
        nominal = (w > 0.0) & ~eye
        keep = jnp.ones((n, n), dtype=bool)
        k_drop, k_strag = jax.random.split(key)
        if self.drop_rate > 0.0:
            keep &= jax.random.bernoulli(k_drop, 1.0 - self.drop_rate, (n, n))
        if self.straggler_rate > 0.0:
            sends = jax.random.bernoulli(k_strag, 1.0 - self.straggler_rate,
                                         (n,))
            keep &= sends[None, :]  # column j = sender j's outgoing edges
        if self.churn:
            up = self.up_mask(t, n)
            keep &= up[None, :] & up[:, None]
        realized = nominal & keep
        mask = realized | eye  # self loops survive everything
        w_masked = w * mask
        col_mass = jnp.sum(w_masked, axis=0, keepdims=True)  # (1, N)
        w_real = w_masked / col_mass
        out_degree = jnp.sum(realized, axis=0).astype(jnp.int32)  # per sender
        dropped = (jnp.sum(nominal.astype(jnp.int32))
                   - jnp.sum(out_degree)).astype(jnp.int32)
        diag = {"net_out_degree": out_degree,
                "net_dropped_edges": dropped}
        if with_adjacency:
            diag["net_adj"] = mask
        return w_real, diag

    def realize_sparse(
        self, idx: jnp.ndarray, vals: jnp.ndarray, key: jax.Array, t, *,
        with_adjacency: bool = False,
    ) -> tuple[jnp.ndarray, dict[str, Any]]:
        """Padded-CSR twin of :meth:`realize` — never touches an (N, N) W.

        ``idx`` / ``vals`` are the (N, K) receiver-major padded CSR of
        ``repro.core.topology.padded_csr``: slot (i, k) means sender
        ``idx[i, k]`` reaches receiver i with weight ``vals[i, k]``; pad
        slots carry the receiver's own index with weight 0 and are neither
        edges nor self loops here (``vals > 0`` is the support test).
        Returns the renormalized ``vals`` (same shape — the sparsity
        pattern is static, dropped edges just carry weight 0) plus the same
        diagnostics as the dense path. Column renormalization reduces each
        sender's surviving mass with a segment-sum over the edge list, so
        the realized weights are column-stochastic to f32 round-off but not
        bit-identical to the dense path's axis-0 sum — only the *fault-free*
        sparse mix is pinned bit-exact against dense (tests/test_sparse.py).

        The per-slot fault draws consume the same ``fault_key`` fold as the
        dense path but a differently-shaped Bernoulli, so dense and sparse
        fault streams are independent samples of the same model.
        """
        n, k = idx.shape
        rows = jnp.arange(n, dtype=idx.dtype)[:, None]  # receiver per slot
        self_slot = idx == rows  # true self loops AND zero-weight pads
        nominal = (vals > 0.0) & ~self_slot
        keep = jnp.ones((n, k), dtype=bool)
        k_drop, k_strag = jax.random.split(key)
        if self.drop_rate > 0.0:
            keep &= jax.random.bernoulli(k_drop, 1.0 - self.drop_rate, (n, k))
        if self.straggler_rate > 0.0:
            sends = jax.random.bernoulli(k_strag, 1.0 - self.straggler_rate,
                                         (n,))
            keep &= sends[idx]  # slot's sender missed the round everywhere
        if self.churn:
            up = self.up_mask(t, n)
            keep &= up[idx] & up[:, None]
        realized = nominal & keep
        mask = realized | self_slot  # self loops survive everything
        vals_masked = vals * mask
        col_mass = jax.ops.segment_sum(  # (N,) surviving mass per sender
            vals_masked.reshape(-1), idx.reshape(-1), num_segments=n)
        vals_real = vals_masked / col_mass[idx]
        out_degree = jax.ops.segment_sum(
            realized.astype(jnp.int32).reshape(-1), idx.reshape(-1),
            num_segments=n)
        dropped = (jnp.sum(nominal.astype(jnp.int32))
                   - jnp.sum(out_degree)).astype(jnp.int32)
        diag = {"net_out_degree": out_degree,
                "net_dropped_edges": dropped}
        if with_adjacency:
            # Scatter-add then threshold: integer adds are deterministic
            # where a duplicated boolean scatter would not be.
            hits = jnp.zeros((n, n), jnp.int32).at[
                jnp.broadcast_to(rows, (n, k)), idx
            ].add(mask.astype(jnp.int32))
            diag["net_adj"] = hits > 0
        return vals_real, diag
