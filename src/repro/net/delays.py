"""DelayModel — bounded-delay asynchronous push-sum for the protocol engine.

Every runtime in the repo is bulk-synchronous; the harshest thing
:mod:`repro.net.faults` can do to a straggler is erase its messages. Real
decentralized networks degrade more gently: messages arrive *late*, nodes
tick at different rates, and only pathologically-old traffic is given up
on. This module models exactly that regime — the ROADMAP's async +
heterogeneous scenario lab — as a frozen, hashable model riding on
:class:`repro.engine.ProtocolPlan` (the ``FaultModel`` pattern):

* **bounded random delays** — every sent ``(value, weight)`` message is
  assigned a seeded delay in ``{0..max_delay}``; delayed mass waits in a
  per-receiver arrival calendar (:class:`Mailbox`) carried through the
  compiled scan and is mixed in the round it lands.
* **staleness timeouts** — with probability ``timeout_rate`` a message
  would exceed the staleness bound ``B = max_delay``; it times out at send
  time and its mass is re-credited to the sender's self-loop. Delivered-
  late beats never-delivered: where ``FaultModel`` drops a straggler's
  edge and renormalizes, the delay model reroutes the same mass, so
  nothing is ever lost.
* **heterogeneous node rates** — node ``i`` participates every
  ``rates[i]`` rounds; in between it neither perturbs nor sends, holds its
  entire state (no self-loop scaling), and arrivals accumulate in its
  inbox until the next active round.

Push-sum makes the bookkeeping trivial: Eq. 9 only needs every sender's
outgoing mass to sum to 1 *eventually*, and because the mass travels on
the messages themselves, conservation holds for any delay pattern — the
invariant becomes ``state + inbox + calendar`` mass ``== N`` (the
``async_mass_mean`` diagnostic; pinned to 1e-5 in tests/test_async.py and
watched by :class:`repro.obs.WatchdogHook`). DP is untouched: the engine
hands this module the *noised* wire payload ``s_noise`` (noise is injected
before enqueue), so every transmitted message carries exactly the Eq.-8
protection of the synchronous protocol.

Randomness discipline mirrors ``FaultModel``: delays and timeouts are
drawn from :meth:`DelayModel.delay_key` — a salted fold
(``DELAY_SALT != FAULT_SALT``) of the engine's per-round key — so the
delay stream is independent of both the noise stream and the fault
stream, identical between the scan engine and the loop driver, and
host-re-derivable from the base key. Faults compose: the engine realizes
the (masked, renormalized) W first and the delay model consumes it.

An inactive ``DelayModel()`` (delay 0, no timeouts, all rates 1) is
dropped at plan build, so the compiled program is bit-identical to the
synchronous engine — packed and pytree, dense and sparse (an acceptance
pin, not an accident).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.pushsum import PushSumState, _mix_dense, sparse_mix

__all__ = ["DelayModel", "Mailbox", "DELAY_SALT"]

# Folded into the round key to derive the delay/timeout stream. Distinct
# from FAULT_SALT ("NETF"): a run with both models active draws two
# independent streams off the same round key.
DELAY_SALT = 0x4E455444  # "NETD"


class Mailbox(NamedTuple):
    """In-flight message mass, carried through the scan next to the state.

    ``cal_s`` / ``cal_a`` are arrival calendars with a leading depth axis
    of ``B = max_delay`` slots: slot ``k`` holds the aggregated messages
    landing ``k + 1`` rounds from now (delay-0 traffic mixes immediately
    and never touches the calendar). ``inbox_s`` / ``inbox_a`` accumulate
    mass that has *arrived* at a node that is not participating this round
    — it is folded into the state at the node's next active round. The
    ``*_s`` fields mirror the runtime form of the protocol state ``s``
    (pytree leaves or the packed ``(N, d_pad)`` buffer; the engine packs
    and unpacks them alongside the state at segment boundaries).
    """

    cal_s: Any             # leaves (B, N, ...)
    cal_a: jnp.ndarray     # (B, N) f32
    inbox_s: Any           # leaves (N, ...)
    inbox_a: jnp.ndarray   # (N,) f32


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Static description of the network's asynchrony.

    Fields:
      max_delay     staleness bound ``B``: sent messages are assigned a
                    uniform random delay in ``{0..B}`` rounds. 0 = every
                    delivery is immediate.
      timeout_rate  per-message probability that delivery would exceed
                    ``B``; the message times out and its mass re-credits
                    the sender's self-loop (the straggler-reroute knob).
      rates         per-node round rates: node ``i`` participates when
                    ``t % rates[i] == 0``. Empty = every node every round.
                    Length must equal the topology's node count.
      seed          reserved fold for running several independent delay
                    streams off one base key.

    Frozen and hashable — it rides on :class:`repro.engine.ProtocolPlan`
    as a trace-time constant.
    """

    max_delay: int = 0
    timeout_rate: float = 0.0
    rates: tuple[int, ...] = ()
    seed: int = 0

    def __post_init__(self):
        if not isinstance(self.max_delay, int) or isinstance(
                self.max_delay, bool) or self.max_delay < 0:
            raise ValueError(
                f"max_delay={self.max_delay!r} must be an int >= 0")
        if not (0.0 <= self.timeout_rate < 1.0):
            raise ValueError(
                f"timeout_rate={self.timeout_rate} must be in [0, 1)")
        for i, r in enumerate(self.rates):
            if not isinstance(r, int) or isinstance(r, bool) or r < 1:
                raise ValueError(
                    f"rates[{i}]={r!r} must be an int >= 1 (node "
                    "participates every r rounds)")

    @property
    def active(self) -> bool:
        """Whether any asynchrony code needs to be emitted at all."""
        return (self.max_delay > 0 or self.timeout_rate > 0.0
                or any(r > 1 for r in self.rates))

    def validate_nodes(self, n_nodes: int) -> None:
        """Raise if ``rates`` doesn't cover the topology (plan-build check)."""
        if self.rates and len(self.rates) != n_nodes:
            raise ValueError(
                f"DelayModel.rates has {len(self.rates)} entries but the "
                f"topology has N={n_nodes} nodes; give one rate per node "
                "(or leave rates empty for all-every-round)")

    # -- key discipline ------------------------------------------------------

    def delay_key(self, round_key: jax.Array) -> jax.Array:
        """The delay stream's key for a round, derived from the engine's
        per-round key (``fold_in(base_key, t)``) by folding the salt and
        the model's ``seed`` — independent of the noise draw and of
        ``FaultModel.fault_key``'s fault stream."""
        return jax.random.fold_in(
            jax.random.fold_in(round_key, DELAY_SALT), self.seed)

    # -- in-scan machinery ---------------------------------------------------

    def active_mask(self, t, n_nodes: int) -> jnp.ndarray:
        """(N,) bool: node participating this round (traced ``t``)."""
        if not self.rates:
            return jnp.ones((n_nodes,), dtype=bool)
        self.validate_nodes(n_nodes)
        rates = jnp.asarray(self.rates, jnp.int32)
        return jnp.mod(jnp.asarray(t, jnp.int32), rates) == 0

    def init_mailbox(self, s: Any) -> Mailbox:
        """Empty mailbox mirroring the runtime form of the state ``s``
        (pytree leaves or the packed buffer — either way leaves are
        ``(N, ...)``)."""
        leaves = jax.tree_util.tree_leaves(s)
        n = leaves[0].shape[0]
        b = self.max_delay
        return Mailbox(
            cal_s=jax.tree_util.tree_map(
                lambda x: jnp.zeros((b,) + x.shape, x.dtype), s),
            cal_a=jnp.zeros((b, n), jnp.float32),
            inbox_s=jax.tree_util.tree_map(jnp.zeros_like, s),
            inbox_a=jnp.zeros((n,), jnp.float32))

    def open_round(
        self,
        push_old: PushSumState,
        mail: Mailbox,
        round_key: jax.Array,
        t,
        *,
        w: jnp.ndarray | None = None,
        sparse_idx: jnp.ndarray | None = None,
        sparse_vals: jnp.ndarray | None = None,
    ) -> tuple[Callable[[PushSumState], PushSumState], Callable[[], tuple]]:
        """One async round as a ``gossip_fn`` closure pair.

        Returns ``(gossip_fn, close)``: the engine hands ``gossip_fn`` to
        ``dpps_step`` in place of the built-in mixing (it receives the
        round's *noised* wire payload as ``push_half`` — DP noise is
        already on every enqueued message), then calls ``close()`` after
        the step for ``(new_mailbox, stats)``. Both the scan engine and
        the session loop driver build the closure from the same operands
        and key folds, so the two drivers stay bit-identical under delays.

        Mixing operands are the round's *realized* weights — pass the
        dense ``w`` or the padded-CSR ``sparse_idx``/``sparse_vals``
        (after ``FaultModel.realize*`` when faults compose). Per-leaf
        arrivals run through the same ``_mix_dense`` / ``sparse_mix``
        primitives as the synchronous gossip, which is what keeps the
        packed and pytree async programs bit-equal in f32.

        Round mechanics (all per-message draws shared by value and
        weight — the ``(value, weight)`` pair travels together):

        * active sender ``j`` keeps ``w_jj x_j`` plus the mass of its
          timed-out messages; each surviving off-diagonal message gets a
          delay ``d``: ``d = 0`` mixes now, ``d >= 1`` lands in calendar
          slot ``d - 1``.
        * every node's arrivals this round = popped calendar slot 0 +
          immediate messages; active receivers fold arrivals + inbox into
          their state, inactive receivers hold state and bank arrivals in
          the inbox.
        * inactive senders contribute nothing (their whole state holds),
          so every column of realized mass still sums to 1 and total mass
          (state + inbox + calendar) is conserved for any configuration.

        Stats (merged into the engine trajectory):
          async_delay_hist     (B+1,) i32 surviving messages per delay
          async_timeouts       () i32 timed-out (rerouted) messages
          async_staleness_max  () i32 max assigned delay (<= B always)
          async_participated   (N,) bool this round's active mask
          async_active         () i32 participating node count
          async_mass_mean      () f32 (state + inbox + calendar mass) / N
          async_inflight_mass  () f32 mass not yet folded into any state
                               (inbox + calendar) — the timeline's
                               in-flight counter series
        """
        if (w is None) == (sparse_idx is None):
            raise ValueError(
                "open_round needs exactly one of w= (dense) or "
                "sparse_idx=/sparse_vals= (padded CSR)")
        out: dict[str, Any] = {}
        b = self.max_delay

        def gossip_fn(push_half: PushSumState) -> PushSumState:
            x_tree, a = push_half.s, push_half.a
            n = a.shape[0]
            act = self.active_mask(t, n)
            k_to, k_dly = jax.random.split(self.delay_key(round_key))

            if w is not None:
                eye = jnp.eye(n, dtype=bool)
                support = (w > 0.0) & ~eye
                sent = support & act[None, :]       # column j = sender j
                shape = (n, n)
                weights = w
                diag_w = jnp.diagonal(w)
                def colsum(m):
                    return jnp.sum(m, axis=0)
            else:
                rows = jnp.arange(n, dtype=sparse_idx.dtype)[:, None]
                self_slot = sparse_idx == rows      # self loops AND pads
                support = (sparse_vals > 0.0) & ~self_slot
                sent = support & act[sparse_idx]
                shape = sparse_idx.shape
                weights = sparse_vals
                diag_w = jnp.sum(sparse_vals * self_slot, axis=1)
                def colsum(m):
                    return jax.ops.segment_sum(
                        m.reshape(-1), sparse_idx.reshape(-1), num_segments=n)

            if self.timeout_rate > 0.0:
                timeout = jax.random.bernoulli(
                    k_to, self.timeout_rate, shape) & sent
            else:
                timeout = jnp.zeros(shape, dtype=bool)
            if b > 0:
                dly = jax.random.randint(k_dly, shape, 0, b + 1)
            else:
                dly = jnp.zeros(shape, jnp.int32)
            surv = sent & ~timeout
            w_surv = weights * surv
            slot_w = [w_surv * (dly == d) for d in range(b + 1)]
            recred = colsum(weights * timeout)          # (N,) per sender
            keep_c = diag_w + recred                    # active senders only

            if w is not None:
                mixes = [lambda x, m=m: _mix_dense(m, x) for m in slot_w]
            else:
                mixes = [lambda x, v=v: sparse_mix(sparse_idx, v, x)
                         for v in slot_w]

            def bcast(v, x):
                return v.reshape(v.shape + (1,) * (x.ndim - 1))

            def step_leaf(x, old, cal, inbox):
                arrive = mixes[0](x)
                if b > 0:
                    arrive = arrive + cal[0]
                inbox_tot = inbox + arrive
                act_x = bcast(act, x)
                keep = bcast(keep_c, x).astype(x.dtype) * x
                new = jnp.where(act_x, keep + inbox_tot, old)
                inbox_new = jnp.where(act_x, jnp.zeros_like(inbox), inbox_tot)
                if b > 0:
                    enq = jnp.stack([mixes[d](x) for d in range(1, b + 1)])
                    cal_new = jnp.concatenate(
                        [cal[1:], jnp.zeros_like(cal[:1])], axis=0) + enq
                else:
                    cal_new = cal
                return new, inbox_new, cal_new

            x_leaves, treedef = jax.tree_util.tree_flatten(x_tree)
            old_leaves = treedef.flatten_up_to(push_old.s)
            cal_leaves = treedef.flatten_up_to(mail.cal_s)
            inbox_leaves = treedef.flatten_up_to(mail.inbox_s)
            trips = [step_leaf(x, o, c, i) for x, o, c, i in
                     zip(x_leaves, old_leaves, cal_leaves, inbox_leaves)]
            s_new = treedef.unflatten([tr[0] for tr in trips])
            inbox_s = treedef.unflatten([tr[1] for tr in trips])
            cal_s = treedef.unflatten([tr[2] for tr in trips])
            a_new, inbox_a, cal_a = step_leaf(a, a, mail.cal_a, mail.inbox_a)

            out["mail"] = Mailbox(cal_s=cal_s, cal_a=cal_a,
                                  inbox_s=inbox_s, inbox_a=inbox_a)
            out["stats"] = {
                "async_delay_hist": jnp.stack([
                    jnp.sum(surv & (dly == d)).astype(jnp.int32)
                    for d in range(b + 1)]),
                "async_timeouts": jnp.sum(timeout).astype(jnp.int32),
                "async_staleness_max": jnp.max(
                    jnp.where(surv, dly, 0)).astype(jnp.int32),
                "async_participated": act,
                "async_active": jnp.sum(act).astype(jnp.int32),
                "async_mass_mean": (jnp.sum(a_new) + jnp.sum(inbox_a)
                                    + jnp.sum(cal_a)) / n,
                "async_inflight_mass": jnp.sum(inbox_a) + jnp.sum(cal_a),
            }
            return PushSumState(s=s_new, a=a_new)

        def close() -> tuple[Mailbox, dict[str, Any]]:
            if "mail" not in out:
                raise RuntimeError(
                    "close() before the gossip ran — open_round's gossip_fn "
                    "must be handed to dpps_step first")
            return out["mail"], out["stats"]

        return gossip_fn, close
